//! The tuning service proper: route handling over the [`super::http`]
//! transport, wired to the sharded store, the batched ingest plane and the
//! checkpointer.
//!
//! The `/v1/suggest` and `/v1/report` hot paths are allocation-free in
//! the HTTP+JSON layers: request bodies are read through the borrowed
//! [`JsonSlice`] (no tree, strings borrow from the connection buffer),
//! session identity is resolved to an interned [`SessionId`] (no key
//! clone), and responses serialize through [`JsonWriter`] into the
//! worker's reusable [`ResponseBuf`].
//!
//! Endpoints (full reference with examples: `docs/API.md`):
//!
//! | method | path                | purpose                                      |
//! |--------|---------------------|----------------------------------------------|
//! | POST   | `/v1/suggest`       | next configuration to evaluate (Eq. 2-3)     |
//! | POST   | `/v1/report`        | enqueue a measured evaluation (batched)      |
//! | POST   | `/v1/suggest/batch` | many suggests in one request, one shard lock |
//! |        |                     | per shard touched (see `DESIGN.md` §Batched) |
//! | POST   | `/v1/report/batch`  | many reports in one request, per-entry       |
//! |        |                     | queued/dropped status                        |
//! | GET    | `/v1/best`          | the session's tuned configuration (Eq. 4)    |
//! | POST   | `/v1/checkpoint`    | force a snapshot of every session            |
//! | POST   | `/v1/sync/push`     | deposit a peer node's arm statistics         |
//! | POST   | `/v1/sync/pull`     | fetch the discount-merged fleet prior        |
//! | GET    | `/v1/trace`         | drain flight-recorder events since a seq     |
//! | GET    | `/v1/debug/session` | full per-session arm statistics              |
//! | GET    | `/healthz`          | liveness + session count                     |
//! | GET    | `/metrics`          | Prometheus counters, latency histograms,     |
//! |        |                     | transport stats, process [`ResourceReport`]  |
//!
//! [`ResourceReport`]: crate::telemetry::ResourceReport

use super::batch::{self, BatchIngest, Enqueue, Report};
use super::checkpoint;
use super::fleet::{self, FleetSnapshot, FleetStore, FleetSync, FleetSyncConfig};
use super::plane::RoutedPlane;
use super::transport::{
    self, ConnCtx, HttpHandler, HttpServer, KeyCacheEntry, Request, ResponseBuf, TransportKind,
    TransportOptions, TransportStats,
};
use super::metrics::{fleet_state_name, ChaosGauges, FleetGauges, Metrics, TraceGauges};
use super::store::{
    AppsCache, KeyRef, PolicyKind, SessionId, Shard, ShardReadGuard, ShardWriteGuard, ShardedStore,
    Tuner,
};
use crate::apps::AppKind;
use crate::chaos::{ChaosConfig, ChaosLayer, HandlerFault};
use crate::device::PowerMode;
use crate::obs::{self, EventKind, Recorder, TraceWriter};
use crate::telemetry::ResourceTracker;
use crate::util::json::{JsonSlice, JsonWriter};
use anyhow::{anyhow, Context, Result};
use std::borrow::Cow;
use std::cell::{Cell, RefCell};
use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration (see `config/` for the `[serve]` TOML section).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8787` (`:0` for an ephemeral port).
    pub addr: String,
    /// HTTP worker threads (blocking transport only).
    pub workers: usize,
    /// Reactor event loops; 0 = auto (one per core). Unlike `workers`,
    /// this does not cap concurrent connections — each loop multiplexes
    /// thousands — so the right value tracks cores, not expected load.
    pub event_loops: usize,
    /// Which transport backend serves the listener.
    pub transport: TransportKind,
    /// Session-store shards; 0 = auto (derived from the event-loop
    /// count so shard ownership divides evenly). Under the routed
    /// reactor plane an explicit value must be a multiple of the event
    /// loops — see [`ServeConfig::resolved_topology`].
    pub shards: usize,
    /// Per-shard report queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Max reports applied per shard-lock acquisition.
    pub max_batch: usize,
    /// Directory for periodic session snapshots (None = stateless).
    pub checkpoint_dir: Option<PathBuf>,
    /// Period between automatic snapshots.
    pub checkpoint_every: Duration,
    /// Warm-start retention `∈ (0, 1]` applied to restored states.
    pub warm_retain: f64,
    /// Fleet leader to sync with (`host:port`; None = standalone node).
    pub leader: Option<String>,
    /// Stable node identity on the sync wire (None = derived from the
    /// bound address).
    pub node_id: Option<String>,
    /// Period between fleet push/pull cycles.
    pub sync_every: Duration,
    /// Retention `∈ (0, 1]` applied when warm-starting a session from a
    /// fleet prior (fleet knowledge biases, never dominates).
    pub fleet_retain: f64,
    /// Half-life for time-decaying fleet evidence (merge-side and on the
    /// installed prior).
    pub fleet_half_life: Duration,
    /// Stream the flight-recorder ring to this binary trace file
    /// (`LASPTRC1` format, decodable by `lasp trace dump`); `None` keeps
    /// tracing in-memory only (`GET /v1/trace`).
    pub trace_file: Option<PathBuf>,
    /// Fault-injection layer (`--chaos <file.toml>` / `[chaos]` section);
    /// `None` = no chaos code on any path (the zero-overhead default).
    pub chaos: Option<ChaosConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8787".to_string(),
            workers: 8,
            event_loops: 0,
            transport: transport::default_kind(),
            shards: 0,
            queue_cap: 4096,
            max_batch: 128,
            checkpoint_dir: None,
            checkpoint_every: Duration::from_secs(30),
            warm_retain: 0.5,
            leader: None,
            node_id: None,
            sync_every: Duration::from_secs(10),
            fleet_retain: 0.3,
            fleet_half_life: Duration::from_secs(600),
            trace_file: None,
            chaos: None,
        }
    }
}

impl ServeConfig {
    /// Sanity-check ranges (also delegated to by `LaspConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.queue_cap == 0 || self.max_batch == 0 {
            return Err(anyhow!("serve: workers/queue_cap/max_batch must be positive"));
        }
        // Shards may be 0 (= auto); explicit values must tile the event
        // loops so the routed plane's ownership map stays balanced.
        self.resolved_topology()?;
        if !(self.warm_retain > 0.0 && self.warm_retain <= 1.0) {
            return Err(anyhow!("serve: warm_retain must lie in (0, 1]"));
        }
        if self.checkpoint_every.is_zero() {
            return Err(anyhow!("serve: checkpoint_every must be positive"));
        }
        if !(self.fleet_retain > 0.0 && self.fleet_retain <= 1.0) {
            return Err(anyhow!("serve: fleet_retain must lie in (0, 1]"));
        }
        if self.sync_every.is_zero() {
            return Err(anyhow!("serve: sync_every must be positive"));
        }
        if self.fleet_half_life.is_zero() {
            return Err(anyhow!("serve: fleet_half_life must be positive"));
        }
        if matches!(&self.leader, Some(l) if l.is_empty()) {
            return Err(anyhow!("serve: leader address must not be empty"));
        }
        if let Some(chaos) = &self.chaos {
            chaos.validate()?;
        }
        Ok(())
    }

    /// How many transport threads this config actually starts: event
    /// loops for the reactor (0 = one per core), `workers` for the
    /// blocking pool.
    pub fn effective_threads(&self) -> usize {
        match self.resolved_topology() {
            Ok((_, threads)) => threads,
            Err(_) => self.workers.max(1),
        }
    }

    /// Resolve `(shards, transport threads)`, applying the `0 = auto`
    /// defaults and the routed plane's tiling rule:
    ///
    /// * reactor, both auto — one loop per core, one shard per loop;
    /// * reactor, explicit loops — shards default to the loop count;
    /// * reactor, explicit shards — loops become the largest divisor of
    ///   the shard count not exceeding the core count, so ownership
    ///   stays balanced on any host;
    /// * reactor, both explicit — rejected unless the shard count is a
    ///   multiple of the loop count;
    /// * blocking — shards default to the worker count; no tiling rule
    ///   (any worker may lock any shard).
    pub fn resolved_topology(&self) -> Result<(usize, usize)> {
        if self.transport == TransportKind::Blocking {
            let shards = if self.shards == 0 { self.workers.max(1) } else { self.shards };
            return Ok((shards, self.workers.max(1)));
        }
        let cores = transport::default_event_loops();
        match (self.shards, self.event_loops) {
            (0, 0) => Ok((cores, cores)),
            (0, l) => Ok((l, l)),
            (s, 0) => {
                let l = (1..=s.min(cores)).rev().find(|l| s % l == 0).unwrap_or(1);
                Ok((s, l))
            }
            (s, l) if s % l != 0 => Err(anyhow!(
                "serve: --shards ({s}) must be a multiple of --event-loops ({l}) so every \
                 event loop owns the same number of shards (pass --shards 0 to derive it)"
            )),
            (s, l) => Ok((s, l)),
        }
    }

    /// Whether this config serves through the routed (shared-nothing)
    /// data plane: reactor event loops exclusively own store shards and
    /// the suggest/report hot path runs lock-free on the owner. Non-unix
    /// builds fall back to the blocking transport and keep the shared
    /// plane, matching the transport layer's own fallback.
    pub(crate) fn is_routed(&self) -> bool {
        cfg!(unix) && self.transport == TransportKind::Reactor
    }
}

/// A request's parameter source: borrowed JSON body (POST) or raw query
/// string (GET). Both resolve values without allocating unless the wire
/// bytes contain escapes.
enum Params<'a> {
    Body(JsonSlice<'a>),
    Query(&'a str),
}

impl<'a> Params<'a> {
    /// `Ok(None)` = absent. A present-but-undecodable query value (e.g.
    /// percent-encoding that is not UTF-8) is an error, never a silent
    /// fall-back to the parameter's default.
    fn get_str(&self, name: &str) -> std::result::Result<Option<Cow<'a, str>>, String> {
        match self {
            Params::Body(b) => {
                let Some(v) = b.get(name) else {
                    return Ok(None);
                };
                if let Some(s) = v.as_str() {
                    return Ok(Some(s));
                }
                // Tolerate numeric values where strings are expected
                // (e.g. a numeric client_id); cold path, may allocate.
                match v.as_f64() {
                    Some(n) => Ok(Some(Cow::Owned(if n.fract() == 0.0 && n.abs() < 1e15 {
                        format!("{}", n as i64)
                    } else {
                        format!("{n}")
                    }))),
                    None => Err(format!("bad {name}")),
                }
            }
            Params::Query(q) => match transport::query_get_raw(q, name) {
                None => Ok(None),
                Some(raw) => match transport::percent_decode(raw) {
                    Some(v) => Ok(Some(v)),
                    None => Err(format!("bad percent-encoding in {name}")),
                },
            },
        }
    }

    /// `Ok(None)` = absent; present but unparsable is an error.
    fn get_f64(&self, name: &str) -> std::result::Result<Option<f64>, String> {
        match self {
            Params::Body(b) => match b.get(name) {
                None => Ok(None),
                Some(v) => v
                    .as_f64()
                    .or_else(|| v.as_str().and_then(|s| s.parse().ok()))
                    .map(Some)
                    .ok_or_else(|| format!("bad {name}")),
            },
            Params::Query(_) => match self.get_str(name)? {
                None => Ok(None),
                Some(s) => s.parse::<f64>().map(Some).map_err(|_| format!("bad {name}")),
            },
        }
    }
}

/// The session identity + objective weights parsed off a request.
struct ParsedKey<'a> {
    client_id: Cow<'a, str>,
    app: AppKind,
    device: PowerMode,
    policy: PolicyKind,
    alpha: f64,
    beta: f64,
}

impl ParsedKey<'_> {
    fn key_ref(&self) -> KeyRef<'_> {
        KeyRef {
            client_id: &*self.client_id,
            app: self.app,
            device: self.device,
            policy: self.policy,
        }
    }
}

/// Read the session identity (+ weights) from a parameter source. Free
/// function (rather than a `TuningService` method) so the transport
/// routing hooks can parse identity before a handler runs.
fn parse_key_with<'a>(
    apps: &AppsCache,
    p: &Params<'a>,
) -> std::result::Result<ParsedKey<'a>, String> {
    let client_id = p.get_str("client_id")?.unwrap_or(Cow::Borrowed(""));
    if client_id.is_empty() {
        return Err("missing client_id".to_string());
    }
    let app: AppKind = p
        .get_str("app")?
        .ok_or_else(|| "missing app".to_string())?
        .parse()
        .map_err(|e: anyhow::Error| format!("{e:#}"))?;
    let device: PowerMode = match p.get_str("device")? {
        Some(d) => d.parse().map_err(|e: anyhow::Error| format!("{e:#}"))?,
        None => PowerMode::Maxn,
    };
    let k = apps.arms(app);
    let policy: PolicyKind = match p.get_str("policy")? {
        Some(s) => s.parse().map_err(|e: anyhow::Error| format!("{e:#}"))?,
        None => PolicyKind::default_for(k),
    };
    let alpha = p.get_f64("alpha")?.unwrap_or(0.8);
    let beta = p.get_f64("beta")?.unwrap_or(0.2);
    if !(0.0..=1.0).contains(&alpha) || !(0.0..=1.0).contains(&beta) || alpha + beta == 0.0 {
        return Err("alpha/beta must lie in [0,1] with alpha+beta > 0".to_string());
    }
    Ok(ParsedKey { client_id, app, device, policy, alpha, beta })
}

thread_local! {
    /// Which routed event loop the current thread is, set once in
    /// `LoopHooks::on_loop_start`. `None` on every non-loop thread
    /// (blocking workers, checkpointer, fleet sync) — the shard-access
    /// helpers and rendezvous waits branch on it.
    static CURRENT_LOOP: Cell<Option<usize>> = const { Cell::new(None) };
}

/// How reward ingestion and shard access are organized — chosen once at
/// boot from the transport kind.
enum DataPlane {
    /// Shared store: any thread may lock any shard; reports drain
    /// through the per-shard updater queues. The blocking transport
    /// (and non-unix builds) serve through this plane.
    Shared(BatchIngest),
    /// Shared-nothing: each reactor event loop exclusively owns the
    /// shards `{s : s % n_loops == loop_idx}`. Single keyed requests
    /// reach their owner by connection re-homing, so suggest/report
    /// touch only loop-owned state — no locks, no queues, no parking.
    /// Cross-loop work (foreign batch groups, checkpoint extraction,
    /// fleet aggregation) rides the plane's per-loop job mailboxes.
    Routed(Arc<RoutedPlane>),
}

/// Mutable shard access under either data-plane discipline. Deref
/// coercion keeps `ShardedStore::get_or_create` and friends oblivious
/// to which discipline produced the reference.
enum ShardRef<'a> {
    /// Routed plane: the calling loop owns the shard; no lock taken.
    Owned(&'a mut Shard),
    /// Shared plane: a plain write guard.
    Locked(ShardWriteGuard<'a>),
}

impl std::ops::Deref for ShardRef<'_> {
    type Target = Shard;
    fn deref(&self) -> &Shard {
        match self {
            ShardRef::Owned(s) => s,
            ShardRef::Locked(g) => g,
        }
    }
}

impl std::ops::DerefMut for ShardRef<'_> {
    fn deref_mut(&mut self) -> &mut Shard {
        match self {
            ShardRef::Owned(s) => s,
            ShardRef::Locked(g) => g,
        }
    }
}

/// Read-only shard access under either discipline (`/v1/best`, the
/// debug surface).
enum ShardReadRef<'a> {
    Owned(&'a Shard),
    Locked(ShardReadGuard<'a>),
}

impl std::ops::Deref for ShardReadRef<'_> {
    type Target = Shard;
    fn deref(&self) -> &Shard {
        match self {
            ShardReadRef::Owned(s) => s,
            ShardReadRef::Locked(g) => g,
        }
    }
}

/// Shared state behind every worker thread.
pub struct TuningService {
    cfg: ServeConfig,
    store: Arc<ShardedStore>,
    apps: Arc<AppsCache>,
    /// Shard-access + reward-ingestion discipline (see [`DataPlane`]).
    plane: DataPlane,
    metrics: Arc<Metrics>,
    transport: Arc<TransportStats>,
    tracker: Mutex<ResourceTracker>,
    /// Per-node snapshot registry for the sync plane (every node can
    /// serve as a leader; see [`super::fleet`]).
    fleet: Arc<FleetStore>,
    /// This node's identity on the sync wire.
    node_id: String,
    /// Last time `/v1/sync/push` refreshed the local warm-start priors —
    /// the fleet-wide merge is O(nodes × scenarios × arms), so it runs
    /// at most once per `PRIOR_REFRESH_MIN` rather than per push.
    prior_refresh: Mutex<Option<Instant>>,
    /// Cached local aggregate served to `/v1/sync/pull` (same TTL): the
    /// session-store scan takes every shard's read lock, so a large
    /// follower fleet pulling must not re-run it per request.
    local_agg: Mutex<Option<(Instant, Arc<Vec<FleetSnapshot>>)>>,
    /// The flight recorder every layer logs into (see [`crate::obs`]).
    recorder: Arc<Recorder>,
    /// Seeded fault-injection layer; `None` (the default) keeps every
    /// hot path chaos-free — call sites short-circuit on the `Option`.
    chaos: Option<Arc<ChaosLayer>>,
}

/// The service's hooks into the reactor in routed mode: identify each
/// loop thread, drain its job mailbox every tick, and map keyed single
/// requests to their owning loop so the transport can re-home the
/// connection before the handler runs.
struct RoutedHooks {
    plane: Arc<RoutedPlane>,
    store: Arc<ShardedStore>,
    apps: Arc<AppsCache>,
}

impl transport::LoopHooks for RoutedHooks {
    fn on_loop_start(&self, loop_idx: usize, wake: Arc<dyn Fn() + Send + Sync>) {
        CURRENT_LOOP.with(|c| c.set(Some(loop_idx)));
        self.plane.register_wake(loop_idx, wake);
    }

    fn on_tick(&self, loop_idx: usize) {
        self.plane.drain(loop_idx);
    }

    /// Owner lookup for the keyed single-request routes. Parses just
    /// enough of the request to hash the session key — no interning, no
    /// allocation (the body view and the key fields all borrow from the
    /// connection buffer). Returns `None` for batch and non-keyed
    /// routes (they run wherever the connection lives) and for
    /// unparsable requests (the handler rejects those locally without
    /// touching any shard).
    fn route(&self, req: &Request<'_>, ctx: &mut ConnCtx) -> Option<usize> {
        if !matches!(
            (req.method, req.path),
            ("POST", "/v1/suggest")
                | ("POST", "/v1/report")
                | ("GET", "/v1/best")
                | ("GET", "/v1/debug/session")
        ) {
            return None;
        }
        let p = if req.method == "GET" {
            Params::Query(req.query)
        } else {
            match JsonSlice::parse(req.body) {
                Ok(b) => Params::Body(b),
                Err(_) => return None,
            }
        };
        let pk = match parse_key_with(&self.apps, &p) {
            Ok(pk) => pk,
            Err(_) => return None,
        };
        // A keep-alive connection re-sending its cached identity skips
        // even the hash: the entry already knows the shard.
        if let Some(e) = &ctx.key {
            if e.client_id == *pk.client_id
                && e.app == pk.app
                && e.device == pk.device
                && e.policy == pk.policy
            {
                return Some(self.plane.owner_of(e.shard as usize));
            }
        }
        let shard = self.store.shard_of_hash(pk.key_ref().hash64());
        Some(self.plane.owner_of(shard))
    }
}

/// Hard cap on entries per batch request (`/v1/suggest/batch`,
/// `/v1/report/batch`). Oversized batches are rejected whole with 400 —
/// a cap keeps one request from monopolizing a shard lock, and rejecting
/// is cheaper than silently truncating a client's stream.
pub const MAX_BATCH_ENTRIES: usize = 256;

/// One validated batch entry, resolved to its interned session id. The
/// measurement fields are zeroed for suggest entries.
#[derive(Clone, Copy)]
struct EntryPlan {
    id: SessionId,
    shard: u32,
    app: AppKind,
    policy: PolicyKind,
    alpha: f64,
    beta: f64,
    arm: usize,
    time_s: f64,
    power_w: f64,
    seq: Option<u64>,
}

/// Per-entry suggest outcome, written back in entry order.
#[derive(Clone, Copy, Default)]
struct ChoiceSlot {
    arm: usize,
    total_pulls: f64,
}

/// Reusable per-worker-thread scratch for the batch endpoints. Every
/// buffer grows to its high-water mark once and is then only cleared and
/// refilled, so steady-state batch handling allocates nothing — the same
/// discipline as [`ResponseBuf`] on the single-request path.
struct BatchArena {
    /// Validated entries, in request order.
    entries: Vec<EntryPlan>,
    /// Entry indices sorted by (shard, arrival): the shard-grouped visit
    /// order. Stable within a shard, so a session's entries apply in the
    /// order the client sent them (sessions are pinned to one shard).
    order: Vec<u32>,
    /// One bandit scratch shared by every session scored in the batch
    /// (see [`crate::bandit::Scratch`] — `resize` keeps capacity, so
    /// mixed arm counts share one high-water allocation).
    scratch: crate::bandit::Scratch,
    /// Suggest outcomes, indexed by entry.
    choices: Vec<ChoiceSlot>,
    /// Staging for one shard's run of reports.
    reports: Vec<Report>,
    /// Enqueue outcomes in shard-grouped order...
    grouped: Vec<Enqueue>,
    /// ...scattered back to entry order for the response.
    statuses: Vec<Enqueue>,
}

impl BatchArena {
    fn new() -> BatchArena {
        BatchArena {
            entries: Vec::new(),
            order: Vec::new(),
            scratch: crate::bandit::Scratch::new(),
            choices: Vec::new(),
            reports: Vec::new(),
            grouped: Vec::new(),
            statuses: Vec::new(),
        }
    }
}

thread_local! {
    /// One arena per transport thread: reactor event loops and blocking
    /// pool workers are both OS threads that serve one request at a
    /// time, so this is per-event-loop (or per-worker) reuse without
    /// locking.
    static BATCH_ARENA: RefCell<BatchArena> = RefCell::new(BatchArena::new());
}

/// Score one shard's run of suggest-batch entries against `shard`,
/// emitting each entry's outcome through `sink` keyed by its original
/// batch index. Factored out of the handler so the routed plane can run
/// it both inline (runs owned by the handling loop) and inside posted
/// owner-loop jobs. Uses the session's private scoring scratch
/// (`select_traced`); the policy contract guarantees it selects
/// identically to the arena-shared `select_traced_in` variant, so the
/// response bytes match the shared plane bit for bit.
fn score_entries(
    store: &ShardedStore,
    apps: &AppsCache,
    metrics: &Metrics,
    recorder: &Recorder,
    shard: &mut Shard,
    items: impl Iterator<Item = (u32, EntryPlan)>,
    sink: &mut dyn FnMut(u32, ChoiceSlot),
) -> std::result::Result<(), String> {
    for (idx, e) in items {
        let k = apps.arms(e.app);
        let (session, created) = store.get_or_create(shard, e.id, e.alpha, e.beta, k)?;
        session.suggests += 1;
        let warm = created && session.tuner.total_pulls() > 0.0;
        let choice = session.tuner.select_traced();
        let total_pulls = session.tuner.total_pulls();
        store.note_scratch(session);
        if created {
            metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
            recorder.record(
                EventKind::SessionCreate,
                e.id.0 as u64,
                k as u64,
                warm as u64 | (e.policy.code() as u64) << 8,
            );
        }
        let (a, b, c) = obs::pack_suggest(
            e.id.0,
            choice.arm as u32,
            choice.gap,
            choice.explore,
            e.policy.code(),
            total_pulls as u64,
        );
        recorder.record(EventKind::Suggest, a, b, c);
        metrics.suggests.fetch_add(1, Ordering::Relaxed);
        sink(idx, ChoiceSlot { arm: choice.arm, total_pulls });
    }
    Ok(())
}

/// Flight-recorder route code for a request (see [`obs::route`]).
fn route_code(method: &str, path: &str) -> u64 {
    match (method, path) {
        ("POST", "/v1/suggest") => obs::route::SUGGEST,
        ("POST", "/v1/report") => obs::route::REPORT,
        ("POST", "/v1/suggest/batch") => obs::route::SUGGEST_BATCH,
        ("POST", "/v1/report/batch") => obs::route::REPORT_BATCH,
        ("GET", "/v1/best") => obs::route::BEST,
        ("POST", "/v1/checkpoint") => obs::route::CHECKPOINT,
        ("POST", "/v1/sync/push") => obs::route::SYNC_PUSH,
        ("POST", "/v1/sync/pull") => obs::route::SYNC_PULL,
        ("GET", "/v1/trace") => obs::route::TRACE,
        ("GET", "/v1/debug/session") => obs::route::DEBUG_SESSION,
        ("GET", "/healthz") => obs::route::HEALTHZ,
        ("GET", "/metrics") => obs::route::METRICS,
        _ => obs::route::OTHER,
    }
}

/// Minimum interval between full prior-refresh merges in the push
/// handler (a 256-follower leader sees ~50 pushes/s; consecutive merges
/// are near-identical).
const PRIOR_REFRESH_MIN: Duration = Duration::from_secs(1);

impl TuningService {
    /// Route one request, serializing into the worker's reusable buffer.
    /// `ctx` is the per-connection state: which loop the connection
    /// lives on (stamped into `req_start` trace events) and the cached
    /// resolved session key.
    pub fn handle(&self, req: &Request<'_>, ctx: &mut ConnCtx, out: &mut ResponseBuf) {
        self.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let route = route_code(req.method, req.path);
        self.recorder
            .record(EventKind::ReqStart, route, ctx.loop_idx as u64, 0);
        // Chaos handler faults fire after ReqStart so the trace shows the
        // request that was hit; an injected error still flows through the
        // shared epilogue (error counter + ReqEnd) like a real failure.
        let mut faulted = false;
        if let Some(chaos) = &self.chaos {
            match chaos.handler_fault() {
                Some(HandlerFault::Error) => faulted = true,
                Some(HandlerFault::Delay(d)) => std::thread::sleep(d),
                None => {}
            }
        }
        if faulted {
            out.error(503, "chaos: injected handler fault");
        } else {
            self.route(req, ctx, out);
        }
        if out.status() >= 400 {
            self.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
        }
        self.recorder.record(
            EventKind::ReqEnd,
            route,
            out.status() as u64,
            t0.elapsed().as_micros() as u64,
        );
    }

    fn route(&self, req: &Request<'_>, ctx: &mut ConnCtx, out: &mut ResponseBuf) {
        match (req.method, req.path) {
            ("POST", "/v1/suggest") => self.suggest(req, ctx, out),
            ("POST", "/v1/report") => self.report(req, ctx, out),
            ("POST", "/v1/suggest/batch") => self.suggest_batch(req, out),
            ("POST", "/v1/report/batch") => self.report_batch(req, out),
            ("GET", "/v1/best") => self.best(req, ctx, out),
            ("POST", "/v1/checkpoint") => self.checkpoint_now(out),
            ("POST", "/v1/sync/push") => self.sync_push(req, out),
            ("POST", "/v1/sync/pull") => self.sync_pull(req, out),
            ("GET", "/v1/trace") => self.trace(req, out),
            ("GET", "/v1/debug/session") => self.debug_session(req, ctx, out),
            ("GET", "/healthz") => self.healthz(out),
            ("GET", "/metrics") => self.metrics_page(out),
            ("POST" | "GET", _) => out.error(404, "no such endpoint"),
            _ => out.error(405, "method not allowed"),
        }
    }

    /// Read the session identity (+ weights) from a parameter source.
    fn parse_key<'a>(&self, p: &Params<'a>) -> std::result::Result<ParsedKey<'a>, String> {
        parse_key_with(&self.apps, p)
    }

    /// Mutable access to shard `shard_i` under the active plane's
    /// discipline: loop-owned (no lock — `owned_shard_mut`'s debug
    /// assertion is the "suggest/report never parks" claim) in routed
    /// mode, write-locked in shared mode.
    fn shard_mut(&self, shard_i: usize) -> ShardRef<'_> {
        match &self.plane {
            DataPlane::Routed(plane) => {
                debug_assert_eq!(
                    CURRENT_LOOP.with(|c| c.get()),
                    Some(plane.owner_of(shard_i)),
                    "routed shard {shard_i} accessed off its owning loop"
                );
                // Safety: the routing hooks deliver every keyed request
                // to the loop owning its shard (asserted above), and
                // cross-loop work reaches owners through their
                // mailboxes, so this thread is the shard's only
                // accessor while the loops run.
                ShardRef::Owned(unsafe { self.store.owned_shard_mut(shard_i) })
            }
            DataPlane::Shared(_) => ShardRef::Locked(self.store.write_shard(shard_i)),
        }
    }

    /// Read access to shard `shard_i` under the active plane's
    /// discipline (owned reference vs read guard).
    fn shard_read(&self, shard_i: usize) -> ShardReadRef<'_> {
        match &self.plane {
            DataPlane::Routed(plane) => {
                debug_assert_eq!(
                    CURRENT_LOOP.with(|c| c.get()),
                    Some(plane.owner_of(shard_i)),
                    "routed shard {shard_i} read off its owning loop"
                );
                // Safety: as for `shard_mut`.
                ShardReadRef::Owned(unsafe { self.store.owned_shard_mut(shard_i) })
            }
            DataPlane::Shared(_) => ShardReadRef::Locked(self.store.read_shard(shard_i)),
        }
    }

    /// Overwrite (or create) the connection's cached key resolution in
    /// place — the `String` keeps its capacity across key changes.
    fn cache_key(
        &self,
        pk: &ParsedKey<'_>,
        hash: u64,
        shard: usize,
        id: SessionId,
        ctx: &mut ConnCtx,
    ) {
        match &mut ctx.key {
            Some(e) => {
                e.client_id.clear();
                e.client_id.push_str(&pk.client_id);
                e.app = pk.app;
                e.device = pk.device;
                e.policy = pk.policy;
                e.hash = hash;
                e.shard = shard as u32;
                e.id = id;
            }
            None => {
                ctx.key = Some(KeyCacheEntry {
                    client_id: pk.client_id.to_string(),
                    app: pk.app,
                    device: pk.device,
                    policy: pk.policy,
                    hash,
                    shard: shard as u32,
                    id,
                });
            }
        }
    }

    /// Resolve a parsed key to its `(shard, session id)` through the
    /// connection's key cache: a keep-alive client re-sending the same
    /// identity skips the FNV hash and the interner probe entirely. A
    /// key change re-resolves and overwrites the entry.
    fn resolve_key(&self, pk: &ParsedKey<'_>, ctx: &mut ConnCtx) -> (usize, SessionId) {
        if let Some(e) = &ctx.key {
            if e.client_id == *pk.client_id
                && e.app == pk.app
                && e.device == pk.device
                && e.policy == pk.policy
            {
                self.transport.key_cache_hits.fetch_add(1, Ordering::Relaxed);
                return (e.shard as usize, e.id);
            }
        }
        let kref = pk.key_ref();
        let hash = kref.hash64();
        let id = self.store.intern(&kref, hash);
        let shard = self.store.shard_of_hash(hash);
        self.cache_key(pk, hash, shard, id, ctx);
        (shard, id)
    }

    /// Like [`TuningService::resolve_key`] but read-only: a cache miss
    /// probes the interner without creating an entry (`/v1/best` and
    /// the debug surface must not mint ids for unknown sessions).
    fn resolve_key_lookup(
        &self,
        pk: &ParsedKey<'_>,
        ctx: &mut ConnCtx,
    ) -> Option<(usize, SessionId)> {
        if let Some(e) = &ctx.key {
            if e.client_id == *pk.client_id
                && e.app == pk.app
                && e.device == pk.device
                && e.policy == pk.policy
            {
                self.transport.key_cache_hits.fetch_add(1, Ordering::Relaxed);
                return Some((e.shard as usize, e.id));
            }
        }
        let kref = pk.key_ref();
        let hash = kref.hash64();
        let id = self.store.lookup(&kref, hash)?;
        let shard = self.store.shard_of_hash(hash);
        self.cache_key(pk, hash, shard, id, ctx);
        Some((shard, id))
    }

    fn suggest(&self, req: &Request<'_>, ctx: &mut ConnCtx, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        let p = Params::Body(body);
        let pk = match self.parse_key(&p) {
            Ok(x) => x,
            Err(e) => return out.error(400, &e),
        };
        let (shard_i, id) = self.resolve_key(&pk, ctx);
        let k = self.apps.arms(pk.app);
        let (choice, total_pulls, created, warm) = {
            let mut shard = self.shard_mut(shard_i);
            let (session, created) =
                match self.store.get_or_create(&mut shard, id, pk.alpha, pk.beta, k) {
                    Ok(x) => x,
                    Err(e) => return out.error(500, &e),
                };
            session.suggests += 1;
            // Warm-started sessions are born with prior pulls.
            let warm = created && session.tuner.total_pulls() > 0.0;
            let choice = session.tuner.select_traced();
            let total_pulls = session.tuner.total_pulls();
            self.store.note_scratch(session);
            (choice, total_pulls, created, warm)
        };
        let arm = choice.arm;
        if created {
            self.metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
            self.recorder.record(
                EventKind::SessionCreate,
                id.0 as u64,
                k as u64,
                warm as u64 | (pk.policy.code() as u64) << 8,
            );
        }
        let (a, b, c) = obs::pack_suggest(
            id.0,
            arm as u32,
            choice.gap,
            choice.explore,
            pk.policy.code(),
            total_pulls as u64,
        );
        self.recorder.record(EventKind::Suggest, a, b, c);
        self.metrics.suggests.fetch_add(1, Ordering::Relaxed);
        self.apps.describe_into(pk.app, arm, &mut out.scratch);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("arm", arm as f64);
        w.field_str("config", &out.scratch);
        w.field_num("shard", shard_i as f64);
        w.field_num("total_pulls", total_pulls);
        w.end_obj();
        self.metrics.suggest_latency.observe(t0.elapsed());
    }

    fn report(&self, req: &Request<'_>, ctx: &mut ConnCtx, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        let p = Params::Body(body);
        let pk = match self.parse_key(&p) {
            Ok(x) => x,
            Err(e) => return out.error(400, &e),
        };
        // Strict arm conversion: negative, fractional or oversized
        // numbers are rejected instead of silently truncated.
        let arm = match body.get("arm").and_then(|v| v.as_usize()) {
            Some(a) => a,
            None => return out.error(400, "missing/invalid arm"),
        };
        let (time_s, power_w) = match (
            body.get("time_s").and_then(|v| v.as_f64()),
            body.get("power_w").and_then(|v| v.as_f64()),
        ) {
            (Some(t), Some(p)) if t.is_finite() && t > 0.0 && p.is_finite() && p >= 0.0 => (t, p),
            _ => return out.error(400, "missing/invalid time_s or power_w"),
        };
        // Optional client sequence number: when present, duplicate and
        // reordered deliveries inside the per-session window are absorbed
        // by the shard updater instead of double-counting the reward.
        let seq = match body.get("seq") {
            None => None,
            Some(v) => match v.as_usize() {
                Some(s) => Some(s as u64),
                None => return out.error(400, "invalid seq (expect a non-negative integer)"),
            },
        };
        let (shard_i, id) = self.resolve_key(&pk, ctx);
        let report = Report {
            id,
            app: pk.app,
            alpha: pk.alpha,
            beta: pk.beta,
            arm,
            time_s,
            power_w,
            seq,
        };
        match &self.plane {
            DataPlane::Shared(ingest) => match ingest.enqueue(shard_i, report, &self.metrics) {
                Ok(Enqueue::Queued) => {
                    self.metrics.reports_enqueued.fetch_add(1, Ordering::Relaxed);
                    out.set_status(202);
                    let mut w = JsonWriter::new(&mut out.body);
                    w.begin_obj();
                    w.field_bool("queued", true);
                    w.field_num("shard", shard_i as f64);
                    w.end_obj();
                }
                Ok(Enqueue::Dropped) => out.error(503, "report queue full"),
                Err(e) => out.error(503, &e),
            },
            DataPlane::Routed(_) => {
                // Owner-loop inline apply: the connection was re-homed
                // to this shard's owner, so the reward goes through the
                // same `apply_one` path as the shard updaters — same
                // seq-window dedup, same chaos duplicate injection —
                // without any queue. The wire response is byte-identical
                // to the queued path ("queued" = accepted).
                {
                    let mut shard = self.shard_mut(shard_i);
                    for _ in 0..batch::chaos_copies(self.chaos.as_deref(), shard_i) {
                        batch::apply_one(
                            &report,
                            &self.store,
                            &mut shard,
                            &self.apps,
                            &self.metrics,
                            &self.recorder,
                        );
                    }
                }
                self.metrics.reports_enqueued.fetch_add(1, Ordering::Relaxed);
                out.set_status(202);
                let mut w = JsonWriter::new(&mut out.body);
                w.begin_obj();
                w.field_bool("queued", true);
                w.field_num("shard", shard_i as f64);
                w.end_obj();
            }
        }
        self.metrics.report_latency.observe(t0.elapsed());
    }

    /// Shared validation for both batch endpoints: parse the `entries`
    /// array, reject malformed or ambiguous input *atomically* (every
    /// entry is validated before any session state changes, so a 4xx
    /// means nothing was applied), and resolve each entry to its
    /// interned session id. `with_report` additionally requires the
    /// measurement fields. On success the arena holds the entry plans
    /// and the shard-grouped visit order; returns the entry count.
    fn parse_batch(
        &self,
        body: &JsonSlice<'_>,
        with_report: bool,
        arena: &mut BatchArena,
    ) -> std::result::Result<usize, (u16, String)> {
        // Duplicate keys are grammatical JSON but ambiguous (`get`
        // returns the first occurrence, tree parsers keep the last):
        // reject instead of guessing which value the client meant.
        if body.has_duplicate_keys() {
            return Err((400, "duplicate keys in request object".to_string()));
        }
        let entries_v = match body.get("entries") {
            Some(v) if v.is_arr() => v,
            Some(_) => return Err((400, "entries must be an array".to_string())),
            None => return Err((400, "missing entries array".to_string())),
        };
        arena.entries.clear();
        for (i, entry) in entries_v.items().enumerate() {
            if arena.entries.len() >= MAX_BATCH_ENTRIES {
                return Err((400, format!("too many entries (max {MAX_BATCH_ENTRIES})")));
            }
            if !entry.is_obj() {
                return Err((400, format!("entry {i}: not an object")));
            }
            if entry.has_duplicate_keys() {
                return Err((400, format!("entry {i}: duplicate keys")));
            }
            let p = Params::Body(entry);
            let pk = self.parse_key(&p).map_err(|e| (400, format!("entry {i}: {e}")))?;
            let mut plan = EntryPlan {
                id: SessionId(0),
                shard: 0,
                app: pk.app,
                policy: pk.policy,
                alpha: pk.alpha,
                beta: pk.beta,
                arm: 0,
                time_s: 0.0,
                power_w: 0.0,
                seq: None,
            };
            if with_report {
                // Same strictness as the single-report path: arm range is
                // checked at apply time (`Tuner::observe`), everything
                // else here.
                plan.arm = match entry.get("arm").and_then(|v| v.as_usize()) {
                    Some(a) => a,
                    None => return Err((400, format!("entry {i}: missing/invalid arm"))),
                };
                (plan.time_s, plan.power_w) = match (
                    entry.get("time_s").and_then(|v| v.as_f64()),
                    entry.get("power_w").and_then(|v| v.as_f64()),
                ) {
                    (Some(t), Some(pw))
                        if t.is_finite() && t > 0.0 && pw.is_finite() && pw >= 0.0 =>
                    {
                        (t, pw)
                    }
                    _ => {
                        return Err((
                            400,
                            format!("entry {i}: missing/invalid time_s or power_w"),
                        ))
                    }
                };
                plan.seq = match entry.get("seq") {
                    None => None,
                    Some(v) => match v.as_usize() {
                        Some(s) => Some(s as u64),
                        None => {
                            return Err((
                                400,
                                format!("entry {i}: invalid seq (expect a non-negative integer)"),
                            ))
                        }
                    },
                };
            }
            let kref = pk.key_ref();
            let hash = kref.hash64();
            plan.id = self.store.intern(&kref, hash);
            plan.shard = self.store.shard_of_hash(hash) as u32;
            arena.entries.push(plan);
        }
        if arena.entries.is_empty() {
            return Err((400, "empty batch".to_string()));
        }
        // Shard-grouped visit order: each shard lock is taken once per
        // batch. `sort_unstable` on a (shard, arrival) key keeps a
        // session's entries in client order within its shard.
        arena.order.clear();
        arena.order.extend(0..arena.entries.len() as u32);
        let entries = &arena.entries;
        arena
            .order
            .sort_unstable_by_key(|&i| ((entries[i as usize].shard as u64) << 32) | i as u64);
        Ok(arena.entries.len())
    }

    /// `POST /v1/suggest/batch`: many suggests in one request. Entries
    /// are validated as a unit (any bad entry rejects the whole batch
    /// with 400 and no state change), grouped by shard so each shard
    /// write lock is taken once, and scored through one shared bandit
    /// scratch. Results come back in entry order.
    fn suggest_batch(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        BATCH_ARENA.with(|cell| {
            let arena = &mut *cell.borrow_mut();
            let n = match self.parse_batch(&body, false, arena) {
                Ok(n) => n,
                Err((code, e)) => return out.error(code, &e),
            };
            arena.choices.clear();
            arena.choices.resize(n, ChoiceSlot::default());
            let BatchArena { entries, order, scratch, choices, .. } = arena;
            match &self.plane {
                DataPlane::Shared(_) => {
                    let mut pos = 0usize;
                    while pos < order.len() {
                        let shard_i = entries[order[pos] as usize].shard as usize;
                        let mut shard = self.store.write_shard(shard_i);
                        while pos < order.len()
                            && entries[order[pos] as usize].shard as usize == shard_i
                        {
                            let idx = order[pos] as usize;
                            let e = &entries[idx];
                            let k = self.apps.arms(e.app);
                            let (session, created) = match self
                                .store
                                .get_or_create(&mut shard, e.id, e.alpha, e.beta, k)
                            {
                                Ok(x) => x,
                                Err(err) => return out.error(500, &err),
                            };
                            session.suggests += 1;
                            let warm = created && session.tuner.total_pulls() > 0.0;
                            let choice = session.tuner.select_traced_in(scratch);
                            let total_pulls = session.tuner.total_pulls();
                            self.store.note_scratch(session);
                            if created {
                                self.metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
                                self.recorder.record(
                                    EventKind::SessionCreate,
                                    e.id.0 as u64,
                                    k as u64,
                                    warm as u64 | (e.policy.code() as u64) << 8,
                                );
                            }
                            let (a, b, c) = obs::pack_suggest(
                                e.id.0,
                                choice.arm as u32,
                                choice.gap,
                                choice.explore,
                                e.policy.code(),
                                total_pulls as u64,
                            );
                            self.recorder.record(EventKind::Suggest, a, b, c);
                            self.metrics.suggests.fetch_add(1, Ordering::Relaxed);
                            choices[idx] = ChoiceSlot { arm: choice.arm, total_pulls };
                            pos += 1;
                        }
                    }
                }
                DataPlane::Routed(plane) => {
                    if let Err((code, e)) =
                        self.suggest_batch_routed(plane, entries, order, choices)
                    {
                        return out.error(code, &e);
                    }
                }
            }
            self.metrics.batch_size.observe(n as u64);
            let mut w = JsonWriter::new(&mut out.body);
            w.begin_obj();
            w.field_num("count", n as f64);
            w.key("results");
            w.begin_arr();
            for (i, e) in entries.iter().enumerate() {
                out.scratch.clear();
                self.apps.describe_into(e.app, choices[i].arm, &mut out.scratch);
                w.begin_obj();
                w.field_num("arm", choices[i].arm as f64);
                w.field_str("config", &out.scratch);
                w.field_num("shard", e.shard as f64);
                w.field_num("total_pulls", choices[i].total_pulls);
                w.end_obj();
            }
            w.end_arr();
            w.end_obj();
            self.metrics.suggest_latency.observe(t0.elapsed());
        })
    }

    /// The routed plane's `/v1/suggest/batch` core: walk the
    /// shard-grouped visit order, score runs owned by this loop inline,
    /// post every foreign run to its owner's mailbox, then rendezvous.
    /// While waiting, this loop drains its *own* mailbox, so two loops
    /// batch-posting to each other both make progress; jobs are depth-1
    /// (they never post), which makes the rendezvous deadlock-free.
    fn suggest_batch_routed(
        &self,
        plane: &Arc<RoutedPlane>,
        entries: &[EntryPlan],
        order: &[u32],
        choices: &mut [ChoiceSlot],
    ) -> std::result::Result<(), (u16, String)> {
        type SuggestSlot = Arc<Mutex<(Vec<(u32, ChoiceSlot)>, Option<String>)>>;
        let me = CURRENT_LOOP
            .with(|c| c.get())
            .expect("routed batch handler off an event loop");
        let mut pending: Vec<(Arc<AtomicBool>, SuggestSlot)> = Vec::new();
        let mut pos = 0usize;
        while pos < order.len() {
            let shard_i = entries[order[pos] as usize].shard as usize;
            let run_start = pos;
            while pos < order.len() && entries[order[pos] as usize].shard as usize == shard_i {
                pos += 1;
            }
            let run = &order[run_start..pos];
            if plane.owner_of(shard_i) == me {
                // Safety: this loop owns `shard_i` (checked above).
                let shard = unsafe { self.store.owned_shard_mut(shard_i) };
                score_entries(
                    &self.store,
                    &self.apps,
                    &self.metrics,
                    &self.recorder,
                    shard,
                    run.iter().map(|&i| (i, entries[i as usize])),
                    &mut |i, c| choices[i as usize] = c,
                )
                .map_err(|e| (500u16, e))?;
            } else {
                let items: Vec<(u32, EntryPlan)> =
                    run.iter().map(|&i| (i, entries[i as usize])).collect();
                let done = Arc::new(AtomicBool::new(false));
                let slot: SuggestSlot = Arc::new(Mutex::new((Vec::new(), None)));
                let store = self.store.clone();
                let apps = self.apps.clone();
                let metrics = self.metrics.clone();
                let recorder = self.recorder.clone();
                let plane2 = plane.clone();
                let (d, s) = (done.clone(), slot.clone());
                plane.post(
                    plane.owner_of(shard_i),
                    Box::new(move || {
                        debug_assert_eq!(
                            CURRENT_LOOP.with(|c| c.get()),
                            Some(plane2.owner_of(shard_i)),
                            "suggest-batch job off its owner loop"
                        );
                        // Safety: jobs in a loop's mailbox run only on
                        // that loop's thread.
                        let shard = unsafe { store.owned_shard_mut(shard_i) };
                        let mut results = Vec::with_capacity(items.len());
                        let err = score_entries(
                            &store,
                            &apps,
                            &metrics,
                            &recorder,
                            shard,
                            items.iter().copied(),
                            &mut |i, c| results.push((i, c)),
                        )
                        .err();
                        if let Ok(mut g) = s.lock() {
                            *g = (results, err);
                        }
                        d.store(true, Ordering::Release);
                    }),
                );
                pending.push((done, slot));
            }
        }
        for (done, slot) in pending {
            while !done.load(Ordering::Acquire) {
                if !plane.live() {
                    return Err((503, "server shutting down".to_string()));
                }
                plane.drain(me);
                std::thread::yield_now();
            }
            let (results, err) = match slot.lock() {
                Ok(mut g) => std::mem::take(&mut *g),
                Err(_) => return Err((500, "batch scoring job panicked".to_string())),
            };
            if let Some(e) = err {
                return Err((500, e));
            }
            for (i, c) in results {
                choices[i as usize] = c;
            }
        }
        Ok(())
    }

    /// `POST /v1/report/batch`: many reports in one request. Validation
    /// is all-or-nothing (400, nothing enqueued); *enqueueing* is
    /// per-entry — an entry hitting a full shard queue is dropped and
    /// counted individually (`lasp_serve_reports_dropped_total`, status
    /// `"dropped"` in the response) while its neighbors proceed, so one
    /// saturated shard degrades entries, never whole batches. Always 202
    /// once validation passes; per-entry outcomes ride in `results`.
    fn report_batch(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        BATCH_ARENA.with(|cell| {
            let arena = &mut *cell.borrow_mut();
            let n = match self.parse_batch(&body, true, arena) {
                Ok(n) => n,
                Err((code, e)) => return out.error(code, &e),
            };
            let BatchArena { entries, order, reports, grouped, statuses, .. } = arena;
            statuses.clear();
            statuses.resize(n, Enqueue::Dropped);
            grouped.clear();
            let mut pos = 0usize;
            while pos < order.len() {
                let shard_i = entries[order[pos] as usize].shard as usize;
                let run_start = pos;
                reports.clear();
                while pos < order.len()
                    && entries[order[pos] as usize].shard as usize == shard_i
                {
                    let e = &entries[order[pos] as usize];
                    reports.push(Report {
                        id: e.id,
                        app: e.app,
                        alpha: e.alpha,
                        beta: e.beta,
                        arm: e.arm,
                        time_s: e.time_s,
                        power_w: e.power_w,
                        seq: e.seq,
                    });
                    pos += 1;
                }
                match &self.plane {
                    DataPlane::Shared(ingest) => {
                        let base = grouped.len();
                        if let Err(e) =
                            ingest.enqueue_group(shard_i, reports, &self.metrics, grouped)
                        {
                            return out.error(503, &e);
                        }
                        for (j, &idx) in order[run_start..pos].iter().enumerate() {
                            statuses[idx as usize] = grouped[base + j];
                        }
                    }
                    DataPlane::Routed(plane) => {
                        // Applying (inline on owned shards, via the
                        // owner's mailbox otherwise) replaces queueing:
                        // there is no bounded queue to overflow, so
                        // every validated entry is "queued". Foreign
                        // runs are fire-and-forget — 202 means
                        // accepted, and the per-loop mailbox is FIFO,
                        // so a session's reports still apply in the
                        // order the client sent them.
                        if plane.owner_of(shard_i)
                            == CURRENT_LOOP
                                .with(|c| c.get())
                                .expect("routed batch handler off an event loop")
                        {
                            // Safety: this loop owns `shard_i`.
                            let shard = unsafe { self.store.owned_shard_mut(shard_i) };
                            for r in reports.iter() {
                                for _ in 0..batch::chaos_copies(self.chaos.as_deref(), shard_i)
                                {
                                    batch::apply_one(
                                        r,
                                        &self.store,
                                        &mut *shard,
                                        &self.apps,
                                        &self.metrics,
                                        &self.recorder,
                                    );
                                }
                            }
                        } else {
                            let run: Vec<Report> = reports.drain(..).collect();
                            let store = self.store.clone();
                            let apps = self.apps.clone();
                            let metrics = self.metrics.clone();
                            let recorder = self.recorder.clone();
                            let chaos = self.chaos.clone();
                            let plane2 = plane.clone();
                            plane.post(
                                plane.owner_of(shard_i),
                                Box::new(move || {
                                    debug_assert_eq!(
                                        CURRENT_LOOP.with(|c| c.get()),
                                        Some(plane2.owner_of(shard_i)),
                                        "report-batch job off its owner loop"
                                    );
                                    // Safety: owner-loop mailbox job.
                                    let shard = unsafe { store.owned_shard_mut(shard_i) };
                                    for r in &run {
                                        for _ in
                                            0..batch::chaos_copies(chaos.as_deref(), shard_i)
                                        {
                                            batch::apply_one(
                                                r, &store, &mut *shard, &apps, &metrics,
                                                &recorder,
                                            );
                                        }
                                    }
                                }),
                            );
                        }
                        for &idx in &order[run_start..pos] {
                            statuses[idx as usize] = Enqueue::Queued;
                        }
                    }
                }
            }
            let queued = statuses.iter().filter(|&&s| s == Enqueue::Queued).count();
            self.metrics.reports_enqueued.fetch_add(queued as u64, Ordering::Relaxed);
            self.metrics.batch_size.observe(n as u64);
            out.set_status(202);
            let mut w = JsonWriter::new(&mut out.body);
            w.begin_obj();
            w.field_num("queued", queued as f64);
            w.field_num("dropped", (n - queued) as f64);
            w.key("results");
            w.begin_arr();
            for (i, e) in entries.iter().enumerate() {
                w.begin_obj();
                w.field_str(
                    "status",
                    match statuses[i] {
                        Enqueue::Queued => "queued",
                        Enqueue::Dropped => "dropped",
                    },
                );
                w.field_num("shard", e.shard as f64);
                w.end_obj();
            }
            w.end_arr();
            w.end_obj();
            self.metrics.report_latency.observe(t0.elapsed());
        })
    }

    fn best(&self, req: &Request<'_>, ctx: &mut ConnCtx, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let p = Params::Query(req.query);
        let pk = match self.parse_key(&p) {
            Ok(x) => x,
            Err(e) => return out.error(400, &e),
        };
        // Read-only surface: never interns (a miss probes, it does not
        // mint an id), never takes a write lock.
        let Some((shard_i, id)) = self.resolve_key_lookup(&pk, ctx) else {
            return out.error(404, "unknown session");
        };
        let shard = self.shard_read(shard_i);
        let Some(session) = shard.sessions.get(&id.0) else {
            return out.error(404, "unknown session");
        };
        let best = session.tuner.most_selected();
        self.apps.describe_into(pk.app, best, &mut out.scratch);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("arm", best as f64);
        w.field_str("config", &out.scratch);
        w.field_num("pulls_of_best", session.tuner.counts()[best]);
        w.field_num("total_pulls", session.tuner.total_pulls());
        w.field_num("suggests", session.suggests as f64);
        w.field_num("reports", session.reports as f64);
        w.field_str("policy", session.tuner.name());
        if let Some((mean_t, mean_p)) = session.tuner.mean_of(best) {
            w.field_num("mean_time_s", mean_t);
            w.field_num("mean_power_w", mean_p);
        }
        w.end_obj();
        drop(shard);
        self.metrics.best_latency.observe(t0.elapsed());
    }

    /// Run every loop/shard's `work` with exclusive access to that shard
    /// and collect `(shard index, result)` pairs. On the shared plane this
    /// would be a lock sweep; callers only reach here on the routed plane,
    /// where each shard's work is posted as a job to its owning event loop
    /// (shards this thread already owns run inline). While waiting, an
    /// event-loop requester drains its *own* mailbox so two loops
    /// scatter-gathering at each other both make progress; a control
    /// thread (checkpointer, fleet sync) just sleeps. Shards whose owner
    /// never ran the job within the deadline — a stalled or stopped loop —
    /// are *skipped*, not fatal: checkpoints and fleet aggregates degrade
    /// to partial coverage rather than wedging the requester (see
    /// DESIGN.md §Shared-nothing data plane, failure semantics).
    fn scatter_gather<T: Send + 'static>(
        &self,
        plane: &Arc<RoutedPlane>,
        work: Arc<dyn Fn(&Shard, usize) -> T + Send + Sync>,
    ) -> Vec<(usize, T)> {
        let me = CURRENT_LOOP.with(|c| c.get());
        let n_shards = self.store.num_shards();
        let mut out: Vec<(usize, T)> = Vec::with_capacity(n_shards);
        type Slot<T> = Arc<(Mutex<Vec<(usize, T)>>, AtomicU64)>;
        let slot: Slot<T> = Arc::new((Mutex::new(Vec::new()), AtomicU64::new(0)));
        let mut posted = 0u64;
        for l in 0..plane.n_loops() {
            if Some(l) == me {
                // Shards owned by the requesting loop: safe to touch
                // directly, no rendezvous needed.
                for s in plane.shards_of(l) {
                    let shard = unsafe { self.store.owned_shard_mut(s) };
                    out.push((s, work(shard, s)));
                }
                continue;
            }
            let shards: Vec<usize> = plane.shards_of(l).collect();
            posted += 1;
            let slot = slot.clone();
            let work = work.clone();
            let store = self.store.clone();
            let plane2 = plane.clone();
            plane.post(
                l,
                Box::new(move || {
                    let mut results = Vec::with_capacity(shards.len());
                    for s in shards {
                        debug_assert_eq!(
                            CURRENT_LOOP.with(|c| c.get()),
                            Some(plane2.owner_of(s)),
                            "scatter-gather job ran off the owning loop"
                        );
                        let shard = unsafe { store.owned_shard_mut(s) };
                        results.push((s, work(shard, s)));
                    }
                    if let Ok(mut v) = slot.0.lock() {
                        v.extend(results);
                    }
                    slot.1.fetch_add(1, Ordering::Release);
                }),
            );
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        while slot.1.load(Ordering::Acquire) < posted {
            if !plane.live() || Instant::now() >= deadline {
                break; // stalled/stopped loops: return what completed
            }
            match me {
                Some(l) => {
                    plane.drain(l);
                    std::thread::yield_now();
                }
                None => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        if let Ok(mut v) = slot.0.lock() {
            out.append(&mut v);
        }
        out.sort_unstable_by_key(|(s, _)| *s);
        out
    }

    /// Snapshot every shard into `dir`. Shared plane: the classic
    /// read-lock sweep. Routed plane: serialization runs on each shard's
    /// owning loop (message passing, no locks on owned state) and the
    /// file I/O happens here, wherever the snapshot was requested.
    ///
    /// Partial write failures degrade to a smaller snapshot (the
    /// per-file retry discipline lives in `checkpoint::write_payloads`),
    /// but a cycle where *every* write failed surfaces as an error so
    /// `/v1/checkpoint` reports 500 instead of a vacuous success.
    fn run_checkpoint(&self, dir: &Path) -> Result<usize> {
        let failed_before = self.metrics.checkpoint_failures.load(Ordering::Relaxed);
        let written = match &self.plane {
            DataPlane::Shared(_) => checkpoint::snapshot_with(
                &self.store,
                dir,
                self.chaos.as_deref(),
                Some(&self.metrics.checkpoint_failures),
            )?,
            DataPlane::Routed(plane) => {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
                let parts = self.scatter_gather(
                    plane,
                    Arc::new(|shard: &Shard, _| checkpoint::shard_payloads(shard)),
                );
                let mut written = 0usize;
                for (_, payloads) in parts {
                    written += checkpoint::write_payloads(
                        &payloads,
                        dir,
                        self.chaos.as_deref(),
                        Some(&self.metrics.checkpoint_failures),
                    );
                }
                written
            }
        };
        let failed = self
            .metrics
            .checkpoint_failures
            .load(Ordering::Relaxed)
            .saturating_sub(failed_before);
        if written == 0 && failed > 0 {
            return Err(anyhow!(
                "checkpoint wrote no sessions ({failed} write attempts failed)"
            ));
        }
        Ok(written)
    }

    fn checkpoint_now(&self, out: &mut ResponseBuf) {
        let Some(dir) = &self.cfg.checkpoint_dir else {
            return out.error(400, "no checkpoint_dir configured");
        };
        let t0 = Instant::now();
        match self.run_checkpoint(dir) {
            Ok(n) => {
                let took = t0.elapsed();
                self.metrics.checkpoints.fetch_add(1, Ordering::Relaxed);
                self.metrics.checkpoint_sessions.fetch_add(n as u64, Ordering::Relaxed);
                self.metrics.checkpoint_latency.observe(took);
                self.recorder.record(
                    EventKind::Checkpoint,
                    n as u64,
                    took.as_micros() as u64,
                    0,
                );
                let mut w = JsonWriter::new(&mut out.body);
                w.begin_obj();
                w.field_num("sessions", n as f64);
                w.end_obj();
            }
            Err(e) => out.error(500, &format!("{e:#}")),
        }
    }

    /// Read the mandatory `node_id` off a sync request body.
    fn sync_node_id<'a>(body: &JsonSlice<'a>) -> std::result::Result<Cow<'a, str>, String> {
        match body.get("node_id").and_then(|v| v.as_str()) {
            Some(id) if !id.is_empty() => Ok(id),
            _ => Err("missing node_id".to_string()),
        }
    }

    /// `POST /v1/sync/push`: store a peer's snapshots under its node id
    /// (replace semantics — repeated pushes are idempotent), then refresh
    /// this node's own warm-start priors from everything remote.
    fn sync_push(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        let node_id = match Self::sync_node_id(&body) {
            Ok(id) => id,
            Err(e) => return out.error(400, &e),
        };
        if node_id.as_ref() == self.node_id.as_str() {
            // A leader flag pointing a node at itself would echo its own
            // statistics back as "remote" evidence; refuse loudly.
            return out.error(400, "node cannot sync with itself (check --leader)");
        }
        let snaps_v = match body.get("snapshots") {
            Some(v) if v.is_arr() => v,
            _ => return out.error(400, "missing snapshots array"),
        };
        let mut snapshots = Vec::new();
        for item in snaps_v.items() {
            match FleetSnapshot::from_slice(&item) {
                Ok(s) => snapshots.push(s),
                Err(e) => return out.error(400, &format!("bad snapshot: {e}")),
            }
        }
        let accepted = self.fleet.absorb(node_id.as_ref(), snapshots);
        self.metrics
            .fleet_push_snapshots
            .fetch_add(accepted as u64, Ordering::Relaxed);
        // Pushes teach this node something: refresh the local warm-start
        // priors from the full remote merge — throttled, since the merge
        // scans every node slot and back-to-back pushes barely change
        // it. (Local sessions are not folded in — they already hold
        // their own evidence.)
        let refresh_due = {
            let mut last = match self.prior_refresh.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            match *last {
                Some(t) if t.elapsed() < PRIOR_REFRESH_MIN => false,
                _ => {
                    *last = Some(Instant::now());
                    true
                }
            }
        };
        if refresh_due {
            let merged = self.fleet.merged(None, None);
            fleet::install_priors(&merged, &self.store, &self.apps);
        }
        let nodes = self.fleet.node_count();
        self.recorder
            .record(EventKind::FleetMerge, accepted as u64, nodes as u64, 0);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("accepted", accepted as f64);
        w.field_num("nodes", nodes as f64);
        w.end_obj();
        self.metrics.sync_push_latency.observe(t0.elapsed());
    }

    /// The node's contribution to the fleet. Shared plane: a read-lock
    /// sweep ([`fleet::aggregate_local`]). Routed plane: each owning loop
    /// folds its shards into a partial accumulator via message passing,
    /// merged here — no shard locks.
    fn compute_local_aggregate(&self) -> Vec<FleetSnapshot> {
        match &self.plane {
            DataPlane::Shared(_) => fleet::aggregate_local(&self.store),
            DataPlane::Routed(plane) => {
                let parts = self.scatter_gather(
                    plane,
                    Arc::new(|shard: &Shard, _| {
                        let mut acc = fleet::FleetAcc::new();
                        fleet::aggregate_shard_into(shard, &mut acc);
                        acc
                    }),
                );
                let mut merged = fleet::FleetAcc::new();
                for (_, acc) in parts {
                    fleet::merge_acc(&mut merged, acc);
                }
                fleet::acc_into_snapshots(merged)
            }
        }
    }

    /// The node's local aggregate, recomputed at most once per
    /// `PRIOR_REFRESH_MIN`. On the shared plane concurrent pulls block on
    /// the cache lock and share one scan (holding it across the scan
    /// prevents a stampede). On the routed plane *blocking* here would
    /// deadlock two event loops scatter-gathering at each other through
    /// the same cache, so a contended lock falls back to an uncached
    /// recompute — both waiters keep draining their own mailboxes and
    /// make progress.
    fn cached_local_aggregate(&self) -> Arc<Vec<FleetSnapshot>> {
        let mut guard = match &self.plane {
            DataPlane::Shared(_) => match self.local_agg.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            },
            DataPlane::Routed(_) => match self.local_agg.try_lock() {
                Ok(g) => g,
                Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
                Err(std::sync::TryLockError::WouldBlock) => {
                    return Arc::new(self.compute_local_aggregate());
                }
            },
        };
        if let Some((at, snaps)) = guard.as_ref() {
            if at.elapsed() < PRIOR_REFRESH_MIN {
                return snaps.clone();
            }
        }
        let fresh = Arc::new(self.compute_local_aggregate());
        *guard = Some((Instant::now(), fresh.clone()));
        fresh
    }

    /// `POST /v1/sync/pull`: serve the discount-merged knowledge of every
    /// other node plus this node's (lightly cached) local aggregate.
    fn sync_pull(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let t0 = Instant::now();
        let body = match JsonSlice::parse(req.body) {
            Ok(b) => b,
            Err(e) => return out.error(400, &format!("bad JSON: {e}")),
        };
        let node_id = match Self::sync_node_id(&body) {
            Ok(id) => id,
            Err(e) => return out.error(400, &e),
        };
        let local = self.cached_local_aggregate();
        let merged = self
            .fleet
            .merged(Some(node_id.as_ref()), Some((self.node_id.as_str(), local.as_slice())));
        self.metrics.fleet_pulls_served.fetch_add(1, Ordering::Relaxed);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_str("node_id", &self.node_id);
        w.key("snapshots");
        w.begin_arr();
        for s in &merged {
            s.write_json(&mut w);
        }
        w.end_arr();
        w.end_obj();
        self.metrics.sync_pull_latency.observe(t0.elapsed());
    }

    /// `GET /v1/trace?since=<seq>&limit=<n>`: drain flight-recorder
    /// events with `seq >= since` as decoded JSON. Cold path — may
    /// allocate. `next_since` is the cursor to resume from; a jump in
    /// `seq` between drains marks ring overwrites (`overwritten` counts
    /// them globally).
    fn trace(&self, req: &Request<'_>, out: &mut ResponseBuf) {
        let p = Params::Query(req.query);
        let since = match p.get_f64("since") {
            Ok(v) => v.unwrap_or(0.0) as u64,
            Err(e) => return out.error(400, &e),
        };
        let limit = match p.get_f64("limit") {
            Ok(Some(v)) if v >= 1.0 => (v as usize).min(65_536),
            Ok(Some(_)) => return out.error(400, "limit must be >= 1"),
            Ok(None) => 4096,
            Err(e) => return out.error(400, &e),
        };
        let mut events = Vec::new();
        self.recorder.drain_since(since, &mut events);
        let truncated = events.len() > limit;
        events.truncate(limit);
        let next_since = events.last().map_or(since, |e| e.seq + 1);
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("next_since", next_since as f64);
        w.field_num("recorded", self.recorder.recorded() as f64);
        w.field_num("overwritten", self.recorder.overwritten() as f64);
        w.field_str(
            "fleet_state",
            fleet_state_name(self.metrics.fleet_state.load(Ordering::Relaxed)),
        );
        w.field_bool("truncated", truncated);
        w.key("events");
        w.begin_arr();
        for e in &events {
            obs::write_event_json(e, &mut w);
        }
        w.end_arr();
        w.end_obj();
    }

    /// `GET /v1/debug/session?...`: full per-session arm statistics for
    /// one session (same query key as `/v1/best`). Read-only; emits
    /// every pulled arm (capped by `limit`, default 512, index order)
    /// with pull counts and mean measurements, plus a regret-vs-best
    /// proxy: Σ pulls·(weighted cost − best weighted cost) over pulled
    /// arms, using the session's α/β objective weights.
    fn debug_session(&self, req: &Request<'_>, ctx: &mut ConnCtx, out: &mut ResponseBuf) {
        let p = Params::Query(req.query);
        let pk = match self.parse_key(&p) {
            Ok(x) => x,
            Err(e) => return out.error(400, &e),
        };
        let limit = match p.get_f64("limit") {
            Ok(v) => v.map_or(512, |x| x as usize).max(1),
            Err(e) => return out.error(400, &e),
        };
        let Some((shard_i, id)) = self.resolve_key_lookup(&pk, ctx) else {
            return out.error(404, "unknown session");
        };
        let shard = self.shard_read(shard_i);
        let Some(session) = shard.sessions.get(&id.0) else {
            return out.error(404, "unknown session");
        };
        let tuner = &session.tuner;
        let counts = tuner.counts();
        let cost = |t: f64, p: f64| session.alpha * t + session.beta * p;
        // Current-best weighted cost among pulled arms — the proxy's
        // reference point (the tuner's live belief, not ground truth).
        let mut best_cost = f64::INFINITY;
        for (arm, &n) in counts.iter().enumerate() {
            if n > 0.0 {
                if let Some((mt, mp)) = tuner.mean_of(arm) {
                    best_cost = best_cost.min(cost(mt, mp));
                }
            }
        }
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_num("session", id.0 as f64);
        w.field_str("policy", tuner.name());
        w.field_num("policy_code", pk.policy.code() as f64);
        w.field_num("k", tuner.k() as f64);
        w.field_num("total_pulls", tuner.total_pulls());
        w.field_num("suggests", session.suggests as f64);
        w.field_num("reports", session.reports as f64);
        w.field_num("alpha", session.alpha);
        w.field_num("beta", session.beta);
        let best = tuner.most_selected();
        w.field_num("best_arm", best as f64);
        if let Some((mt, mp)) = tuner.mean_of(best) {
            w.field_num("best_mean_time_s", mt);
            w.field_num("best_mean_power_w", mp);
        }
        // Policy internals worth surfacing beyond the shared core.
        if let Tuner::Subset(t) = tuner {
            w.field_num("candidates", t.candidates().len() as f64);
        }
        let mut regret = 0.0;
        let mut emitted = 0usize;
        let mut pulled = 0usize;
        w.key("arms");
        w.begin_arr();
        for (arm, &n) in counts.iter().enumerate() {
            if n <= 0.0 {
                continue;
            }
            pulled += 1;
            let Some((mt, mp)) = tuner.mean_of(arm) else {
                continue;
            };
            if best_cost.is_finite() {
                regret += n * (cost(mt, mp) - best_cost);
            }
            if emitted < limit {
                emitted += 1;
                w.begin_obj();
                w.field_num("arm", arm as f64);
                w.field_num("pulls", n);
                w.field_num("mean_time_s", mt);
                w.field_num("mean_power_w", mp);
                w.end_obj();
            }
        }
        w.end_arr();
        w.field_num("arms_pulled", pulled as f64);
        w.field_bool("arms_truncated", pulled > emitted);
        w.field_num("regret_vs_best_proxy", regret);
        w.end_obj();
        drop(shard);
    }

    fn healthz(&self, out: &mut ResponseBuf) {
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_bool("ok", true);
        w.field_num("uptime_s", self.metrics.uptime_s());
        w.field_num("sessions", self.store.session_count() as f64);
        w.field_num("shards", self.store.num_shards() as f64);
        w.end_obj();
    }

    fn metrics_page(&self, out: &mut ResponseBuf) {
        let resources = {
            let mut tracker = match self.tracker.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            tracker.sample();
            tracker.report()
        };
        let fleet = FleetGauges {
            nodes: self.fleet.node_count(),
            prior_keys: self.store.fleet_prior_keys(),
            warm_starts: self.store.fleet_warm_starts(),
        };
        let trace = TraceGauges {
            recorded: self.recorder.recorded(),
            overwritten: self.recorder.overwritten(),
        };
        let chaos = ChaosGauges {
            enabled: self.chaos.is_some(),
            injections: self.chaos.as_ref().map_or(0, |c| c.injections()),
        };
        // Per-loop ownership gauge (routed plane only): session counts
        // come from the store's atomics, so reading them never touches
        // another loop's shards.
        let loop_sessions: Vec<u64> = match &self.plane {
            DataPlane::Shared(_) => Vec::new(),
            DataPlane::Routed(plane) => (0..plane.n_loops())
                .map(|l| {
                    plane
                        .shards_of(l)
                        .map(|s| self.store.shard_session_count(s) as u64)
                        .sum()
                })
                .collect(),
        };
        let body = self.metrics.render(
            self.store.session_count(),
            self.store.num_shards(),
            &self.transport,
            &resources,
            fleet,
            trace,
            chaos,
            &loop_sessions,
        );
        out.text(200, &body);
    }
}

/// A running server. Dropping the handle leaks the threads; call
/// [`ServerHandle::shutdown`] for an orderly stop (drains report queues,
/// writes a final checkpoint) or [`ServerHandle::wait`] to park forever.
pub struct ServerHandle {
    addr: SocketAddr,
    http: HttpServer,
    service: Arc<TuningService>,
    stop_checkpointer: Arc<AtomicBool>,
    checkpointer: Option<JoinHandle<()>>,
    fleet_sync: Option<FleetSync>,
    trace_writer: Option<TraceWriter>,
    restored: usize,
}

impl ServerHandle {
    /// The bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// This node's identity on the fleet-sync wire.
    pub fn node_id(&self) -> &str {
        &self.service.node_id
    }

    /// Sessions warm-started from the checkpoint directory at boot.
    pub fn restored_sessions(&self) -> usize {
        self.restored
    }

    /// Transport counters (connections, requests, alloc events) — the
    /// perf baseline reads these to certify the zero-allocation path.
    pub fn transport_stats(&self) -> Arc<TransportStats> {
        self.service.transport.clone()
    }

    /// Scratch-buffer growth events across every live session's bandit
    /// core — the bandit-layer counterpart of
    /// [`TransportStats::alloc_events`]: flat in steady state, so the
    /// end-to-end zero-allocation assertion covers the policy layer too.
    pub fn bandit_scratch_growths(&self) -> u64 {
        self.service.store.scratch_growth_total()
    }

    /// The server's flight recorder (tests and embedding tools drain it
    /// directly; HTTP clients use `GET /v1/trace`).
    pub fn recorder(&self) -> Arc<Recorder> {
        self.service.recorder.clone()
    }

    /// Orderly shutdown: stop fleet sync and HTTP, drain report queues,
    /// final snapshot.
    pub fn shutdown(mut self) -> Result<()> {
        if let Some(mut sync) = self.fleet_sync.take() {
            sync.stop();
        }
        self.http.stop();
        match &self.service.plane {
            DataPlane::Shared(ingest) => ingest.stop(),
            // Loops are joined by http.stop(); retiring the plane lets
            // any straggler rendezvous (a control thread mid
            // scatter-gather) bail instead of waiting on dead loops.
            DataPlane::Routed(plane) => plane.retire(),
        }
        self.stop_checkpointer.store(true, Ordering::SeqCst);
        if let Some(h) = self.checkpointer {
            let _ = h.join();
        }
        // Final ring drain + flush to the binary trace file.
        if let Some(mut tw) = self.trace_writer.take() {
            tw.stop();
        }
        if let Some(dir) = &self.service.cfg.checkpoint_dir {
            checkpoint::snapshot(&self.service.store, dir)
                .context("final shutdown checkpoint")?;
        }
        Ok(())
    }

    /// Block the calling thread for the life of the server (CLI mode).
    pub fn wait(self) {
        self.http.join();
    }
}

/// Boot the service: restore checkpoints, start ingest, bind, serve,
/// and (when a leader is configured) start the fleet-sync thread.
pub fn start(cfg: ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    let (n_shards, n_threads) = cfg.resolved_topology()?;
    let store = Arc::new(
        ShardedStore::new(n_shards).with_fleet_tuning(cfg.fleet_retain, cfg.fleet_half_life),
    );
    let apps = Arc::new(AppsCache::new());
    let metrics = Arc::new(Metrics::new());
    let transport = Arc::new(TransportStats::default());
    let fleet = Arc::new(FleetStore::new(cfg.fleet_half_life));

    let mut restored = 0;
    if let Some(dir) = &cfg.checkpoint_dir {
        restored = checkpoint::restore(&store, &apps, dir, cfg.warm_retain)?;
        metrics.sessions_restored.fetch_add(restored as u64, Ordering::Relaxed);
    }

    // Bind before constructing the service: the node's default sync
    // identity is derived from the resolved (ephemeral ports included)
    // bound address.
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let bound = listener.local_addr().context("resolving bound address")?;
    let node_id = cfg
        .node_id
        .clone()
        .unwrap_or_else(|| format!("node-{bound}"));

    let recorder = Arc::new(Recorder::for_workers(n_threads));
    let trace_writer = match &cfg.trace_file {
        Some(path) => Some(TraceWriter::start(recorder.clone(), path.clone())?),
        None => None,
    };
    // The chaos layer is built once and shared by every injection
    // surface; `None` keeps each surface's hot path a plain branch.
    let chaos = cfg
        .chaos
        .clone()
        .map(|c| Arc::new(ChaosLayer::new(c, recorder.clone())));
    // Data-plane choice (DESIGN.md §Shared-nothing data plane): the
    // reactor transport gets the routed, shard-per-loop plane; the
    // blocking transport (and non-unix builds, where the reactor falls
    // back to a poll loop without re-homing support) keeps the shared
    // lock-based plane with background ingest updaters.
    let routed_plane = cfg.is_routed().then(|| Arc::new(RoutedPlane::new(n_threads, n_shards)));
    let plane = match &routed_plane {
        Some(p) => DataPlane::Routed(p.clone()),
        None => DataPlane::Shared(BatchIngest::start(
            store.clone(),
            apps.clone(),
            metrics.clone(),
            recorder.clone(),
            cfg.queue_cap,
            cfg.max_batch,
            chaos.clone(),
        )),
    };
    let service = Arc::new(TuningService {
        cfg: cfg.clone(),
        store: store.clone(),
        apps: apps.clone(),
        plane,
        metrics: metrics.clone(),
        transport: transport.clone(),
        tracker: Mutex::new(ResourceTracker::start()),
        fleet,
        node_id: node_id.clone(),
        prior_refresh: Mutex::new(None),
        local_agg: Mutex::new(None),
        recorder: recorder.clone(),
        chaos: chaos.clone(),
    });

    let handler: HttpHandler = {
        let service = service.clone();
        Arc::new(move |req: &Request<'_>, ctx: &mut ConnCtx, out: &mut ResponseBuf| {
            service.handle(req, ctx, out)
        })
    };
    // Routed plane: hand the transport the ownership-aware hooks so
    // keyed requests re-home to their owning loop and each loop drains
    // its job mailbox between poll rounds.
    let hooks = routed_plane.as_ref().map(|p| {
        Arc::new(RoutedHooks {
            plane: p.clone(),
            store: store.clone(),
            apps: apps.clone(),
        }) as Arc<dyn transport::LoopHooks>
    });
    let http = HttpServer::start_with_opts(
        listener,
        handler,
        TransportOptions {
            kind: cfg.transport,
            threads: n_threads,
            stats: transport,
            chaos: chaos.clone(),
            recorder: Some(recorder.clone()),
            hooks,
        },
    )?;
    let addr = http.addr();

    // Follower plane: periodic push/pull against the configured leader.
    // Best-effort by design — an unreachable leader leaves the node
    // serving standalone and only bumps `fleet_sync_errors_total`.
    let fleet_sync = cfg.leader.clone().map(|leader| {
        // The aggregator is injected so the sync thread inherits the
        // data-plane discipline: shared → read-lock sweep, routed →
        // scatter-gather through the owning loops' mailboxes.
        let agg_service = service.clone();
        FleetSync::start(
            FleetSyncConfig {
                leader,
                node_id,
                every: cfg.sync_every,
            },
            store.clone(),
            apps.clone(),
            metrics.clone(),
            recorder.clone(),
            chaos.clone(),
            Arc::new(move || (*agg_service.cached_local_aggregate()).clone()),
        )
    });

    // Periodic checkpointer (only when a directory is configured).
    let stop_checkpointer = Arc::new(AtomicBool::new(false));
    let checkpointer = cfg.checkpoint_dir.clone().map(|dir| {
        // Captures the service (not the raw store) so snapshots follow
        // the active data plane: shard read locks on the shared plane,
        // owner-loop message passing on the routed one.
        let service = service.clone();
        let stop = stop_checkpointer.clone();
        let every = cfg.checkpoint_every;
        std::thread::spawn(move || {
            let mut last = Instant::now();
            loop {
                std::thread::sleep(Duration::from_millis(100));
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if last.elapsed() >= every {
                    let t0 = Instant::now();
                    if let Ok(n) = service.run_checkpoint(&dir) {
                        let took = t0.elapsed();
                        service.metrics.checkpoints.fetch_add(1, Ordering::Relaxed);
                        service
                            .metrics
                            .checkpoint_sessions
                            .fetch_add(n as u64, Ordering::Relaxed);
                        service.metrics.checkpoint_latency.observe(took);
                        service.recorder.record(
                            EventKind::Checkpoint,
                            n as u64,
                            took.as_micros() as u64,
                            0,
                        );
                    }
                    last = Instant::now();
                }
            }
        })
    });

    Ok(ServerHandle {
        addr,
        http,
        service,
        stop_checkpointer,
        checkpointer,
        fleet_sync,
        trace_writer,
        restored,
    })
}
