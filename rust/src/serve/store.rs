//! Sharded session store: the state plane of the tuning service.
//!
//! A *session* is one independent bandit-tuning campaign, keyed by
//! `(client_id, app, device, policy)`. Sessions are partitioned across N
//! shards by a **stable** 64-bit hash of the key (FNV-1a — `DefaultHasher`
//! is randomized per process, which would scramble checkpoint/shard
//! affinity across restarts). Each shard owns its sessions behind a single
//! `Mutex`, so concurrent requests for different shards never contend and
//! the store scales across cores without a global bottleneck; within a
//! shard the critical section is one `select()` or one batched update
//! drain (see [`super::batch`]).

use crate::apps::{self, AppKind, AppModel};
use crate::bandit::persist;
use crate::bandit::reward::RewardState;
use crate::bandit::{Policy, SlidingWindowUcb, SubsetTuner, ThompsonSampler, UcbTuner};
use crate::device::PowerMode;
use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard};

/// Spaces larger than this default to [`SubsetTuner`] (a full UCB init
/// sweep over Hypre's 92,160 arms would dwarf any realistic session).
pub const SUBSET_THRESHOLD: usize = 4096;

/// Candidate-subset size used for very large spaces.
pub const SUBSET_ARMS: usize = 1024;

/// Sliding-window length floor for `swucb` sessions.
const SWUCB_MIN_WINDOW: usize = 512;

/// The bandit policy driving a session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// LASP's UCB1 (the paper's Alg. 1).
    Ucb,
    /// Sliding-window UCB for drifting environments.
    SwUcb,
    /// Gaussian Thompson sampling.
    Thompson,
    /// UCB over a seeded candidate subset (very large spaces).
    Subset,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Ucb => "ucb",
            PolicyKind::SwUcb => "swucb",
            PolicyKind::Thompson => "thompson",
            PolicyKind::Subset => "subset",
        }
    }

    /// Default policy for a `k`-arm space: plain UCB, or subset-UCB when
    /// the init sweep alone would exceed any plausible session budget.
    pub fn default_for(k: usize) -> PolicyKind {
        if k > SUBSET_THRESHOLD {
            PolicyKind::Subset
        } else {
            PolicyKind::Ucb
        }
    }
}

impl std::str::FromStr for PolicyKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "ucb" => Ok(PolicyKind::Ucb),
            "swucb" | "sw-ucb" => Ok(PolicyKind::SwUcb),
            "thompson" => Ok(PolicyKind::Thompson),
            "subset" => Ok(PolicyKind::Subset),
            other => Err(anyhow::anyhow!(
                "unknown policy '{other}' (ucb|swucb|thompson|subset)"
            )),
        }
    }
}

/// Identity of one tuning session.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SessionKey {
    pub client_id: String,
    pub app: AppKind,
    pub device: PowerMode,
    pub policy: PolicyKind,
}

impl SessionKey {
    /// Stable (process- and restart-invariant) FNV-1a hash of the key.
    /// Drives shard placement, checkpoint file names, and the seeds of
    /// stochastic policies, so it must never depend on process state.
    pub fn hash64(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        eat(self.client_id.as_bytes());
        eat(b"\0");
        eat(self.app.name().as_bytes());
        eat(b"\0");
        eat(self.device.name().as_bytes());
        eat(b"\0");
        eat(self.policy.name().as_bytes());
        h
    }
}

/// A session's bandit tuner. An enum (not `Box<dyn Policy>`) so the store
/// can reject malformed client input — out-of-range or out-of-subset arms
/// — as errors instead of panics, and can reach policy-specific state for
/// checkpointing.
pub enum Tuner {
    Ucb(UcbTuner),
    SwUcb(SlidingWindowUcb),
    Thompson(ThompsonSampler),
    Subset(SubsetTuner),
}

impl Tuner {
    /// Construct a tuner, optionally warm-started from a checkpointed
    /// reward state discounted by `retain` (see [`persist::discounted`]).
    pub fn build(
        kind: PolicyKind,
        k: usize,
        alpha: f64,
        beta: f64,
        seed: u64,
        prior: Option<&RewardState>,
        retain: f64,
    ) -> Result<Tuner, String> {
        if k == 0 {
            return Err("empty parameter space".into());
        }
        if !(0.0..=1.0).contains(&alpha) || !(0.0..=1.0).contains(&beta) {
            return Err(format!("alpha/beta out of [0,1]: {alpha}/{beta}"));
        }
        if !(retain > 0.0 && retain <= 1.0) {
            return Err(format!("retain out of (0,1]: {retain}"));
        }
        match kind {
            PolicyKind::Ucb => {
                let mut t = UcbTuner::new(k, alpha, beta);
                if let Some(p) = prior {
                    if p.k() != k {
                        return Err(format!("checkpoint has {} arms, space has {k}", p.k()));
                    }
                    t = t.with_state(persist::discounted(p, retain));
                }
                Ok(Tuner::Ucb(t))
            }
            PolicyKind::SwUcb => {
                let window = (2 * k).max(SWUCB_MIN_WINDOW);
                let mut t = SlidingWindowUcb::new(k, alpha, beta, window);
                if let Some(p) = prior {
                    if p.k() != k {
                        return Err(format!("checkpoint has {} arms, space has {k}", p.k()));
                    }
                    t = t.with_prior(&persist::discounted(p, retain));
                }
                Ok(Tuner::SwUcb(t))
            }
            PolicyKind::Thompson => {
                let mut t = ThompsonSampler::new(k, alpha, beta, seed);
                if let Some(p) = prior {
                    if p.k() != k {
                        return Err(format!("checkpoint has {} arms, space has {k}", p.k()));
                    }
                    t = t.with_state(persist::discounted(p, retain));
                }
                Ok(Tuner::Thompson(t))
            }
            PolicyKind::Subset => {
                let m = SUBSET_ARMS.min(k).max(2.min(k));
                // The candidate draw is seeded by the session-key hash, so
                // a restarted service regenerates the identical subset and
                // a checkpointed subset-space state lines up position-wise.
                let mut t = SubsetTuner::new(k, m, alpha, beta, seed);
                if let Some(p) = prior {
                    if p.k() != m {
                        return Err(format!(
                            "checkpoint subset has {} arms, expected {m}",
                            p.k()
                        ));
                    }
                    t = t.with_prior_state(persist::discounted(p, retain));
                }
                Ok(Tuner::Subset(t))
            }
        }
    }

    /// Arm count of the (full) space.
    pub fn k(&self) -> usize {
        match self {
            Tuner::Ucb(t) => t.k(),
            Tuner::SwUcb(t) => t.k(),
            Tuner::Thompson(t) => t.k(),
            Tuner::Subset(t) => t.k(),
        }
    }

    /// Choose the next arm to evaluate.
    pub fn select(&mut self) -> usize {
        match self {
            Tuner::Ucb(t) => t.select(),
            Tuner::SwUcb(t) => t.select(),
            Tuner::Thompson(t) => t.select(),
            Tuner::Subset(t) => t.select(),
        }
    }

    /// Apply one measured report. Unlike [`Policy::update`], malformed arms
    /// (out of range, or outside a subset tuner's candidate set) are
    /// rejected as errors — a network service must not panic on bad input.
    pub fn observe(&mut self, arm: usize, time_s: f64, power_w: f64) -> Result<(), String> {
        if arm >= self.k() {
            return Err(format!("arm {arm} out of range (k={})", self.k()));
        }
        if !time_s.is_finite() || time_s <= 0.0 || !power_w.is_finite() || power_w < 0.0 {
            return Err(format!("invalid measurement time={time_s} power={power_w}"));
        }
        match self {
            Tuner::Ucb(t) => t.update(arm, time_s, power_w),
            Tuner::SwUcb(t) => t.update(arm, time_s, power_w),
            Tuner::Thompson(t) => t.update(arm, time_s, power_w),
            Tuner::Subset(t) => {
                if !t.contains_arm(arm) {
                    return Err(format!("arm {arm} outside the candidate subset"));
                }
                t.update(arm, time_s, power_w);
            }
        }
        Ok(())
    }

    /// Full-space pull counts.
    pub fn counts(&self) -> &[f64] {
        match self {
            Tuner::Ucb(t) => t.counts(),
            Tuner::SwUcb(t) => t.counts(),
            Tuner::Thompson(t) => t.counts(),
            Tuner::Subset(t) => t.counts(),
        }
    }

    /// Eq. 4: the most frequently selected arm.
    pub fn most_selected(&self) -> usize {
        match self {
            Tuner::Ucb(t) => t.most_selected(),
            Tuner::SwUcb(t) => t.most_selected(),
            Tuner::Thompson(t) => t.most_selected(),
            Tuner::Subset(t) => t.most_selected(),
        }
    }

    /// Total pulls observed.
    pub fn total_pulls(&self) -> f64 {
        match self {
            Tuner::Ucb(t) => t.total_pulls(),
            Tuner::SwUcb(t) => t.total_pulls(),
            Tuner::Thompson(t) => t.total_pulls(),
            Tuner::Subset(t) => t.total_pulls(),
        }
    }

    /// Checkpointable sufficient statistics (subset tuners expose the
    /// subset-space state; positions are subset indices).
    pub fn reward_state(&self) -> Option<&RewardState> {
        match self {
            Tuner::Ucb(t) => t.reward_state(),
            Tuner::SwUcb(t) => t.reward_state(),
            Tuner::Thompson(t) => t.reward_state(),
            Tuner::Subset(t) => t.reward_state(),
        }
    }

    /// Mean observed (time, power) for a full-space arm, if it has been
    /// pulled. Handles the subset tuner's index mapping.
    pub fn mean_of(&self, arm: usize) -> Option<(f64, f64)> {
        let (state, idx) = match self {
            Tuner::Subset(t) => (t.reward_state()?, t.position_of(arm)?),
            other => (other.reward_state()?, arm),
        };
        if idx >= state.k() || state.counts[idx] <= 0.0 {
            return None;
        }
        Some((
            state.tau_sum[idx] / state.counts[idx],
            state.rho_sum[idx] / state.counts[idx],
        ))
    }

    /// Policy name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Tuner::Ucb(t) => t.name(),
            Tuner::SwUcb(t) => t.name(),
            Tuner::Thompson(t) => t.name(),
            Tuner::Subset(t) => t.name(),
        }
    }
}

/// One tuning session: key, weights, tuner, and traffic counters.
pub struct Session {
    pub key: SessionKey,
    pub alpha: f64,
    pub beta: f64,
    pub tuner: Tuner,
    /// Suggest requests served.
    pub suggests: u64,
    /// Reports applied.
    pub reports: u64,
}

/// The sessions owned by one shard.
#[derive(Default)]
pub struct Shard {
    pub sessions: HashMap<SessionKey, Session>,
}

impl Shard {
    /// Fetch a session, creating a cold one on first contact. Returns the
    /// session and whether it was created. A session's `alpha`/`beta` are
    /// fixed at creation; later requests with different weights reuse the
    /// existing tuner (re-keying by weights would fragment state).
    pub fn get_or_create(
        &mut self,
        key: &SessionKey,
        alpha: f64,
        beta: f64,
        k: usize,
    ) -> Result<(&mut Session, bool), String> {
        use std::collections::hash_map::Entry;
        match self.sessions.entry(key.clone()) {
            Entry::Occupied(e) => Ok((e.into_mut(), false)),
            Entry::Vacant(v) => {
                let tuner = Tuner::build(key.policy, k, alpha, beta, key.hash64(), None, 1.0)?;
                let session = Session {
                    key: key.clone(),
                    alpha,
                    beta,
                    tuner,
                    suggests: 0,
                    reports: 0,
                };
                Ok((v.insert(session), true))
            }
        }
    }
}

/// N shards of sessions, keyed by stable hash.
pub struct ShardedStore {
    shards: Vec<Mutex<Shard>>,
}

impl ShardedStore {
    pub fn new(shards: usize) -> ShardedStore {
        assert!(shards > 0, "need at least one shard");
        ShardedStore {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard index owning `key`.
    pub fn shard_of(&self, key: &SessionKey) -> usize {
        (key.hash64() % self.shards.len() as u64) as usize
    }

    /// Lock shard `i` (poisoned locks are recovered — a panicking request
    /// handler must not take the whole shard down with it).
    pub fn lock_shard(&self, i: usize) -> MutexGuard<'_, Shard> {
        match self.shards[i].lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Total sessions across all shards.
    pub fn session_count(&self) -> usize {
        (0..self.num_shards())
            .map(|i| self.lock_shard(i).sessions.len())
            .sum()
    }

    /// Insert a fully built session (checkpoint restore). Existing live
    /// sessions win over checkpointed ones.
    pub fn insert_session(&self, session: Session) {
        let i = self.shard_of(&session.key);
        let mut shard = self.lock_shard(i);
        shard.sessions.entry(session.key.clone()).or_insert(session);
    }
}

/// Immutable per-app lookups shared by every serve component: the four app
/// models are built once, then only read (`AppModel` is `Send + Sync`).
pub struct AppsCache {
    models: Vec<Box<dyn AppModel>>,
}

impl AppsCache {
    pub fn new() -> AppsCache {
        AppsCache {
            models: AppKind::all().iter().map(|&k| apps::build(k)).collect(),
        }
    }

    fn idx(kind: AppKind) -> usize {
        match kind {
            AppKind::Lulesh => 0,
            AppKind::Kripke => 1,
            AppKind::Clomp => 2,
            AppKind::Hypre => 3,
        }
    }

    /// The app model.
    pub fn model(&self, kind: AppKind) -> &dyn AppModel {
        self.models[Self::idx(kind)].as_ref()
    }

    /// Arm count of the app's Table II space.
    pub fn arms(&self, kind: AppKind) -> usize {
        self.model(kind).space().len()
    }

    /// Human-readable rendering of configuration `arm`.
    pub fn describe(&self, kind: AppKind, arm: usize) -> String {
        self.model(kind).space().describe(arm)
    }
}

impl Default for AppsCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(client: &str, app: AppKind, policy: PolicyKind) -> SessionKey {
        SessionKey {
            client_id: client.to_string(),
            app,
            device: PowerMode::Maxn,
            policy,
        }
    }

    #[test]
    fn hash_is_stable_and_field_sensitive() {
        let a = key("alice", AppKind::Clomp, PolicyKind::Ucb);
        assert_eq!(a.hash64(), a.clone().hash64());
        let b = key("alicf", AppKind::Clomp, PolicyKind::Ucb);
        assert_ne!(a.hash64(), b.hash64());
        let c = key("alice", AppKind::Kripke, PolicyKind::Ucb);
        assert_ne!(a.hash64(), c.hash64());
        let d = key("alice", AppKind::Clomp, PolicyKind::Thompson);
        assert_ne!(a.hash64(), d.hash64());
    }

    #[test]
    fn sessions_spread_across_shards() {
        let store = ShardedStore::new(8);
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            let k = key(&format!("client-{i}"), AppKind::Clomp, PolicyKind::Ucb);
            seen.insert(store.shard_of(&k));
        }
        assert!(seen.len() >= 4, "only {} shards used", seen.len());
    }

    #[test]
    fn get_or_create_then_select_and_observe() {
        let store = ShardedStore::new(4);
        let k = key("c1", AppKind::Clomp, PolicyKind::Ucb);
        let i = store.shard_of(&k);
        let mut shard = store.lock_shard(i);
        let (s, created) = shard.get_or_create(&k, 0.8, 0.2, 125).unwrap();
        assert!(created);
        let arm = s.tuner.select();
        assert!(arm < 125);
        s.tuner.observe(arm, 1.0, 5.0).unwrap();
        assert_eq!(s.tuner.total_pulls(), 1.0);
        let (_, created_again) = shard.get_or_create(&k, 0.8, 0.2, 125).unwrap();
        assert!(!created_again);
    }

    #[test]
    fn observe_rejects_bad_input_without_panic() {
        let mut t = Tuner::build(PolicyKind::Ucb, 8, 1.0, 0.0, 1, None, 1.0).unwrap();
        assert!(t.observe(8, 1.0, 1.0).is_err());
        assert!(t.observe(0, f64::NAN, 1.0).is_err());
        assert!(t.observe(0, -1.0, 1.0).is_err());
        assert!(t.observe(0, 1.0, -1.0).is_err());
        assert!(t.observe(0, 1.0, 1.0).is_ok());
    }

    #[test]
    fn subset_rejects_non_candidate_arms() {
        let mut t =
            Tuner::build(PolicyKind::Subset, 92_160, 1.0, 0.0, 99, None, 1.0).unwrap();
        let arm = t.select();
        assert!(t.observe(arm, 1.0, 1.0).is_ok());
        // Find a non-candidate arm: with 1024 of 92160 chosen, scanning a
        // few indices is guaranteed to hit one.
        let miss = (0..92_160)
            .find(|&a| t.observe(a, 1.0, 1.0).is_err())
            .expect("some arm outside the subset");
        assert!(miss < 92_160);
    }

    #[test]
    fn default_policy_scales_with_space() {
        assert_eq!(PolicyKind::default_for(216), PolicyKind::Ucb);
        assert_eq!(PolicyKind::default_for(92_160), PolicyKind::Subset);
    }

    #[test]
    fn warm_start_preserves_means() {
        let mut state = RewardState::new(16);
        for arm in 0..16 {
            for _ in 0..10 {
                state.observe(arm, 1.0 + arm as f64, 5.0);
            }
        }
        let t = Tuner::build(PolicyKind::Ucb, 16, 1.0, 0.0, 7, Some(&state), 0.5).unwrap();
        let (mt, _) = t.mean_of(3).unwrap();
        assert!((mt - 4.0).abs() < 1e-9);
        assert!(t.total_pulls() > 0.0);
    }

    #[test]
    fn warm_start_arm_mismatch_is_error() {
        let state = RewardState::new(8);
        assert!(Tuner::build(PolicyKind::Ucb, 16, 1.0, 0.0, 7, Some(&state), 0.5).is_err());
    }

    #[test]
    fn apps_cache_matches_table2() {
        let cache = AppsCache::new();
        assert_eq!(cache.arms(AppKind::Kripke), 216);
        assert_eq!(cache.arms(AppKind::Hypre), 92_160);
        assert!(!cache.describe(AppKind::Clomp, 0).is_empty());
    }
}
