//! Sharded session store: the state plane of the tuning service.
//!
//! A *session* is one independent bandit-tuning campaign, keyed by
//! `(client_id, app, device, policy)`. Sessions are partitioned across N
//! shards by a **stable** 64-bit hash of the key (FNV-1a — `DefaultHasher`
//! is randomized per process, which would scramble checkpoint/shard
//! affinity across restarts).
//!
//! Two structures keep the request hot path allocation- and clone-free:
//!
//! * a **key interner** maps each distinct session key to a small
//!   [`SessionId`] once; requests build a borrowed [`KeyRef`] from the
//!   parsed request (no `String` clone), resolve it to an id under a
//!   read lock, and from then on every lookup — shard map, report queue,
//!   checkpoint — is by copyable id instead of by cloned key;
//! * each shard owns its sessions behind an `RwLock`, so the read-mostly
//!   surfaces (`/v1/best`, `/metrics` session counts) scan under shared
//!   read locks and never contend with each other, while the write path
//!   (suggest's `select()`, the batched report drain — see
//!   [`super::batch`]) takes the exclusive lock only for its short
//!   critical section.

use crate::apps::{self, AppKind, AppModel};
use crate::bandit::{
    ArmStats, EpsilonGreedy, Policy, SlidingWindowUcb, SubsetTuner, ThompsonSampler, UcbTuner,
};
use crate::device::PowerMode;
use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Duration, Instant};

/// Spaces larger than this default to [`SubsetTuner`] (a full UCB init
/// sweep over Hypre's 92,160 arms would dwarf any realistic session).
pub const SUBSET_THRESHOLD: usize = 4096;

/// Candidate-subset size used for very large spaces.
pub const SUBSET_ARMS: usize = 1024;

/// Sliding-window length floor for `swucb` sessions.
const SWUCB_MIN_WINDOW: usize = 512;

/// Exploration probability for `epsilon` sessions.
const DEFAULT_EPSILON: f64 = 0.1;

/// Minimum decayed effective count for a fleet-prior arm to survive (see
/// [`ShardedStore::fleet_prior_for`]): below a quarter-pull of evidence
/// the warm-start floor would dominate what the decay left.
pub const FLEET_PRIOR_MIN_COUNT: f64 = 0.25;

/// The bandit policy driving a session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// LASP's UCB1 (the paper's Alg. 1).
    Ucb,
    /// Sliding-window UCB for drifting environments.
    SwUcb,
    /// Gaussian Thompson sampling.
    Thompson,
    /// ε-greedy (ablation baseline, checkpointable like every policy
    /// since the unified-core refactor).
    Epsilon,
    /// UCB over a seeded candidate subset (very large spaces).
    Subset,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Ucb => "ucb",
            PolicyKind::SwUcb => "swucb",
            PolicyKind::Thompson => "thompson",
            PolicyKind::Epsilon => "epsilon",
            PolicyKind::Subset => "subset",
        }
    }

    /// Compact wire code used by the flight-recorder event payloads
    /// (see [`crate::obs`]); stable across releases so recorded traces
    /// stay decodable.
    pub fn code(&self) -> u8 {
        match self {
            PolicyKind::Ucb => 0,
            PolicyKind::SwUcb => 1,
            PolicyKind::Thompson => 2,
            PolicyKind::Epsilon => 3,
            PolicyKind::Subset => 4,
        }
    }

    /// Default policy for a `k`-arm space: plain UCB, or subset-UCB when
    /// the init sweep alone would exceed any plausible session budget.
    pub fn default_for(k: usize) -> PolicyKind {
        if k > SUBSET_THRESHOLD {
            PolicyKind::Subset
        } else {
            PolicyKind::Ucb
        }
    }
}

impl std::str::FromStr for PolicyKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "ucb" => Ok(PolicyKind::Ucb),
            "swucb" | "sw-ucb" => Ok(PolicyKind::SwUcb),
            "thompson" => Ok(PolicyKind::Thompson),
            "epsilon" | "eps-greedy" => Ok(PolicyKind::Epsilon),
            "subset" => Ok(PolicyKind::Subset),
            other => Err(anyhow::anyhow!(
                "unknown policy '{other}' (ucb|swucb|thompson|epsilon|subset)"
            )),
        }
    }
}

/// Identity of one *fleet scenario*: the session key minus the client.
/// All sessions tuning the same app on the same device class with the
/// same policy share one reward landscape, so cross-node knowledge (see
/// [`super::fleet`]) is aggregated and transferred at this granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FleetKey {
    pub app: AppKind,
    pub device: PowerMode,
    pub policy: PolicyKind,
}

/// One installed fleet prior: full-space arm statistics merged from the
/// rest of the fleet, stamped with its installation instant so staleness
/// keeps decaying between syncs.
struct FleetPrior {
    state: ArmStats,
    installed: Instant,
}

/// Identity of one tuning session (owned form — held by the interner and
/// by each [`Session`] for checkpointing).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SessionKey {
    pub client_id: String,
    pub app: AppKind,
    pub device: PowerMode,
    pub policy: PolicyKind,
}

impl SessionKey {
    /// Borrowed view for hashing/interning without cloning.
    pub fn as_ref(&self) -> KeyRef<'_> {
        KeyRef {
            client_id: self.client_id.as_str(),
            app: self.app,
            device: self.device,
            policy: self.policy,
        }
    }

    /// Stable (process- and restart-invariant) FNV-1a hash of the key.
    /// Drives shard placement, checkpoint file names, and the seeds of
    /// stochastic policies, so it must never depend on process state.
    pub fn hash64(&self) -> u64 {
        self.as_ref().hash64()
    }
}

/// Borrowed session identity: what the request parser produces. Hashing
/// and interner lookups run on this without ever cloning the client id;
/// the owned [`SessionKey`] is built exactly once per session lifetime
/// (on first contact).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KeyRef<'a> {
    pub client_id: &'a str,
    pub app: AppKind,
    pub device: PowerMode,
    pub policy: PolicyKind,
}

impl KeyRef<'_> {
    /// See [`SessionKey::hash64`].
    pub fn hash64(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        eat(self.client_id.as_bytes());
        eat(b"\0");
        eat(self.app.name().as_bytes());
        eat(b"\0");
        eat(self.device.name().as_bytes());
        eat(b"\0");
        eat(self.policy.name().as_bytes());
        h
    }

    fn matches(&self, key: &SessionKey) -> bool {
        self.client_id == key.client_id
            && self.app == key.app
            && self.device == key.device
            && self.policy == key.policy
    }

    fn to_key(self) -> SessionKey {
        SessionKey {
            client_id: self.client_id.to_string(),
            app: self.app,
            device: self.device,
            policy: self.policy,
        }
    }
}

/// Small, copyable session handle assigned by the interner. Everything
/// downstream of request parsing (shard maps, report queues) keys by
/// this instead of cloning [`SessionKey`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionId(pub u32);

/// A session's bandit tuner. An enum (not `Box<dyn Policy>`) so the store
/// can reject malformed client input — out-of-range or out-of-subset arms
/// — as errors instead of panics, and can reach policy-specific structure
/// (the subset candidate map) where index spaces differ. Everything else
/// dispatches through the one shared [`Policy`] trait.
pub enum Tuner {
    Ucb(UcbTuner),
    SwUcb(SlidingWindowUcb),
    Thompson(ThompsonSampler),
    Epsilon(EpsilonGreedy),
    Subset(SubsetTuner),
}

impl Tuner {
    /// Construct a tuner, optionally warm-started from a prior state
    /// discounted by `retain` (see [`Tuner::warm_start`]).
    pub fn build(
        kind: PolicyKind,
        k: usize,
        alpha: f64,
        beta: f64,
        seed: u64,
        prior: Option<&ArmStats>,
        retain: f64,
    ) -> Result<Tuner, String> {
        if k == 0 {
            return Err("empty parameter space".into());
        }
        if !(0.0..=1.0).contains(&alpha) || !(0.0..=1.0).contains(&beta) {
            return Err(format!("alpha/beta out of [0,1]: {alpha}/{beta}"));
        }
        if !(retain > 0.0 && retain <= 1.0) {
            return Err(format!("retain out of (0,1]: {retain}"));
        }
        let mut tuner = match kind {
            PolicyKind::Ucb => Tuner::Ucb(UcbTuner::new(k, alpha, beta)),
            PolicyKind::SwUcb => {
                let window = (2 * k).max(SWUCB_MIN_WINDOW);
                Tuner::SwUcb(SlidingWindowUcb::new(k, alpha, beta, window))
            }
            PolicyKind::Thompson => Tuner::Thompson(ThompsonSampler::new(k, alpha, beta, seed)),
            PolicyKind::Epsilon => {
                Tuner::Epsilon(EpsilonGreedy::new(k, alpha, beta, DEFAULT_EPSILON, seed))
            }
            PolicyKind::Subset => {
                let m = SUBSET_ARMS.min(k).max(2.min(k));
                // The candidate draw is seeded by the session-key hash, so
                // a restarted service regenerates the identical subset and
                // a checkpointed subset-space state lines up position-wise.
                Tuner::Subset(SubsetTuner::new(k, m, alpha, beta, seed))
            }
        };
        if let Some(p) = prior {
            tuner.warm_start(p, retain)?;
        }
        Ok(tuner)
    }

    /// The one generic warm-start path, used identically by checkpoint
    /// restore and fleet priors for every policy: dimension check →
    /// optional subset projection → discount → [`Policy::warm_start`].
    /// This replaced five hand-rolled per-policy branches; a policy only
    /// customizes how it *absorbs* a prior (via its `warm_start`), never
    /// how one is validated or prepared.
    pub fn warm_start(&mut self, prior: &ArmStats, retain: f64) -> Result<(), String> {
        if !(retain > 0.0 && retain <= 1.0) {
            return Err(format!("retain out of (0,1]: {retain}"));
        }
        let m = self.stats().k();
        // Dimension check. Caveat (pre-existing semantics, preserved):
        // for a subset tuner whose candidate count equals the full space
        // (k <= SUBSET_ARMS), a full-space prior is indistinguishable
        // from a subset-space one and is installed position-wise against
        // the shuffled candidate list. Default policy selection never
        // builds such a tuner (subset only kicks in past
        // SUBSET_THRESHOLD > SUBSET_ARMS); only an explicit
        // policy=subset request on a small space can hit it.
        let prepared = if prior.k() == m {
            Some(prior.discounted(retain))
        } else if let Tuner::Subset(t) = self {
            if prior.k() == t.k() {
                // Full-space prior (e.g. a fleet prior aggregated across
                // nodes whose sessions drew *different* candidate
                // subsets): project onto this session's candidates. Zero
                // overlap degrades to a cold start, not an error.
                let sub = t.project_full_prior(prior);
                if sub.total_pulls() > 0.0 {
                    Some(sub.discounted(retain))
                } else {
                    None
                }
            } else {
                return Err(format!(
                    "checkpoint subset has {} arms, expected {m} (or full {})",
                    prior.k(),
                    t.k()
                ));
            }
        } else {
            return Err(format!(
                "checkpoint has {} arms, space has {m}",
                prior.k()
            ));
        };
        if let Some(p) = prepared {
            self.policy_mut().warm_start(p);
        }
        Ok(())
    }

    /// The policy behind this tuner — the single dispatch point for every
    /// [`Policy`] surface (the old per-method five-arm matches are gone).
    pub fn policy(&self) -> &dyn Policy {
        match self {
            Tuner::Ucb(t) => t,
            Tuner::SwUcb(t) => t,
            Tuner::Thompson(t) => t,
            Tuner::Epsilon(t) => t,
            Tuner::Subset(t) => t,
        }
    }

    fn policy_mut(&mut self) -> &mut dyn Policy {
        match self {
            Tuner::Ucb(t) => t,
            Tuner::SwUcb(t) => t,
            Tuner::Thompson(t) => t,
            Tuner::Epsilon(t) => t,
            Tuner::Subset(t) => t,
        }
    }

    /// Arm count of the (full) space.
    pub fn k(&self) -> usize {
        self.policy().k()
    }

    /// Choose the next arm to evaluate.
    pub fn select(&mut self) -> usize {
        self.policy_mut().select()
    }

    /// [`Tuner::select`] plus the flight-recorder telemetry (top-2 score
    /// gap, explore-vs-exploit flag). Same arm, same RNG draws.
    pub fn select_traced(&mut self) -> crate::bandit::Choice {
        self.policy_mut().select_traced()
    }

    /// [`Tuner::select_traced`] scoring through a caller-provided scratch
    /// — the batched-suggest hot path walks every session in a batch
    /// through one shared warm scratch instead of touching each session's
    /// own buffers. Bit-identical choices, same RNG draws (the
    /// [`Policy::select_traced_in`] contract).
    pub fn select_traced_in(&mut self, scratch: &mut crate::bandit::Scratch) -> crate::bandit::Choice {
        self.policy_mut().select_traced_in(scratch)
    }

    /// Apply one measured report. Unlike [`Policy::update`], malformed arms
    /// (out of range, or outside a subset tuner's candidate set) are
    /// rejected as errors — a network service must not panic on bad input.
    pub fn observe(&mut self, arm: usize, time_s: f64, power_w: f64) -> Result<(), String> {
        if arm >= self.k() {
            return Err(format!("arm {arm} out of range (k={})", self.k()));
        }
        if !time_s.is_finite() || time_s <= 0.0 || !power_w.is_finite() || power_w < 0.0 {
            return Err(format!("invalid measurement time={time_s} power={power_w}"));
        }
        if let Tuner::Subset(t) = self {
            if !t.contains_arm(arm) {
                return Err(format!("arm {arm} outside the candidate subset"));
            }
        }
        self.policy_mut().update(arm, time_s, power_w);
        Ok(())
    }

    /// Full-space pull counts.
    pub fn counts(&self) -> &[f64] {
        self.policy().counts()
    }

    /// Eq. 4: the most frequently selected arm.
    pub fn most_selected(&self) -> usize {
        self.policy().most_selected()
    }

    /// Total pulls observed — O(1) via the shared core's cached counter
    /// (this sits on the suggest hot path).
    pub fn total_pulls(&self) -> f64 {
        self.policy().total_pulls()
    }

    /// The shared arm-statistics core: checkpointable sufficient
    /// statistics for *every* policy (subset tuners expose the
    /// subset-space core; positions are subset indices).
    pub fn stats(&self) -> &ArmStats {
        self.policy().stats()
    }

    /// Mean observed (time, power) for a full-space arm, if it has been
    /// pulled. Handles the subset tuner's index mapping.
    pub fn mean_of(&self, arm: usize) -> Option<(f64, f64)> {
        let (stats, idx) = match self {
            Tuner::Subset(t) => (t.stats(), t.position_of(arm)?),
            other => (other.stats(), arm),
        };
        stats.means_of(idx)
    }

    /// Policy name for reports.
    pub fn name(&self) -> &'static str {
        self.policy().name()
    }
}

/// Width of the per-session report idempotency window, in sequence
/// numbers: duplicates and reorders within the last `SEQ_WINDOW`
/// sequence numbers are absorbed, and anything older than the window is
/// treated as an already-seen duplicate (at-least-once delivery means a
/// very late retry is far more likely than a genuinely new report from
/// the distant past).
pub const SEQ_WINDOW: u64 = 128;

/// Sliding acceptance window over client-assigned report sequence
/// numbers, the idempotency half of at-least-once report delivery: a
/// client that retries or a network that duplicates/reorders delivers
/// the same `seq` more than once, and only the first copy may reach
/// [`ArmStats`]. Fixed-width (`u128` bitmap), no allocation, in-memory
/// only — it intentionally does not survive checkpoint restore, since a
/// restart re-keys client retry state anyway (documented in
/// `DESIGN.md` §Failure model).
#[derive(Debug, Clone, Copy, Default)]
pub struct SeqWindow {
    /// Highest sequence number accepted so far.
    head: u64,
    /// Bit `i` set ⇔ `head - i` has been accepted (bit 0 = `head`).
    bits: u128,
    /// Whether any sequence number has been accepted yet.
    any: bool,
}

impl SeqWindow {
    /// Accept-or-reject one sequence number. Returns `true` exactly once
    /// per distinct `seq` within the window; `false` means "duplicate
    /// (or older than the window): absorb, do not apply".
    pub fn accept(&mut self, seq: u64) -> bool {
        if !self.any {
            self.any = true;
            self.head = seq;
            self.bits = 1;
            return true;
        }
        if seq > self.head {
            let ahead = seq - self.head;
            self.bits = if ahead >= SEQ_WINDOW { 0 } else { self.bits << ahead };
            self.bits |= 1;
            self.head = seq;
            return true;
        }
        let back = self.head - seq;
        if back >= SEQ_WINDOW {
            return false;
        }
        let mask = 1u128 << back;
        if self.bits & mask != 0 {
            return false;
        }
        self.bits |= mask;
        true
    }

    /// Highest accepted sequence number, if any.
    pub fn head(&self) -> Option<u64> {
        self.any.then_some(self.head)
    }
}

/// One tuning session: key, weights, tuner, and traffic counters.
pub struct Session {
    pub key: SessionKey,
    pub alpha: f64,
    pub beta: f64,
    pub tuner: Tuner,
    /// The reward state the tuner started from when it was warm-started
    /// from a fleet prior (tuner-space: subset positions for subset
    /// policies; `None` for cold starts and checkpoint restores).
    /// [`super::fleet::aggregate_local`] subtracts this baseline so
    /// borrowed fleet evidence is never re-exported as this node's own
    /// measurements — without it, every warm-started session would echo
    /// the prior back into the fleet, amplifying it by the session count.
    pub fleet_baseline: Option<ArmStats>,
    /// Suggest requests served.
    pub suggests: u64,
    /// Reports applied.
    pub reports: u64,
    /// Idempotency window over client report sequence numbers (only
    /// consulted for reports that carry a `seq` field).
    pub seq_window: SeqWindow,
    /// Scratch growths of this session's policy already folded into the
    /// store's global counter (see [`ShardedStore::note_scratch`]).
    pub scratch_growths_seen: u64,
}

/// The sessions owned by one shard, keyed by interned [`SessionId`].
#[derive(Default)]
pub struct Shard {
    pub sessions: HashMap<u32, Session>,
}

/// One shard's storage cell: the session map plus the lock that guards
/// it *on the shared (locked) paths only*.
///
/// Two access disciplines coexist:
///
/// * **Locked** ([`ShardedStore::read_shard`] / [`ShardedStore::write_shard`])
///   — the classic `RwLock` protocol, used by the blocking transport,
///   boot-time restore, the final shutdown checkpoint, and unit tests.
/// * **Owned** ([`ShardedStore::owned_shard_mut`]) — the shared-nothing
///   data plane: while the routed reactor is live, each event loop is
///   the *unique* thread touching its owned shards, so it dereferences
///   the cell directly with zero lock operations. A debug assertion
///   (`try_write` must succeed) enforces that the owned path can never
///   observe a held lock — the "suggest/report never parks" contract of
///   DESIGN.md §Shared-nothing data plane.
///
/// Safety: the two disciplines are separated in *time*, not by the type
/// system — owned access happens only between event-loop start and
/// join, during which no locked accessor runs against live-owned shards
/// (cross-cutting consumers go through the owner loop's mailbox
/// instead; see `serve/plane.rs`).
struct ShardCell {
    lock: RwLock<()>,
    data: UnsafeCell<Shard>,
}

// The cell hands out `&mut Shard` across threads under the ownership
// protocol above; the RwLock half covers every shared (locked) access.
unsafe impl Sync for ShardCell {}

impl ShardCell {
    fn new() -> ShardCell {
        ShardCell { lock: RwLock::new(()), data: UnsafeCell::new(Shard::default()) }
    }
}

/// Shared-read guard over one shard (locked discipline).
pub struct ShardReadGuard<'a> {
    _lock: RwLockReadGuard<'a, ()>,
    data: &'a Shard,
}

impl Deref for ShardReadGuard<'_> {
    type Target = Shard;
    fn deref(&self) -> &Shard {
        self.data
    }
}

/// Exclusive guard over one shard (locked discipline).
pub struct ShardWriteGuard<'a> {
    _lock: RwLockWriteGuard<'a, ()>,
    data: &'a mut Shard,
}

impl Deref for ShardWriteGuard<'_> {
    type Target = Shard;
    fn deref(&self) -> &Shard {
        self.data
    }
}

impl DerefMut for ShardWriteGuard<'_> {
    fn deref_mut(&mut self) -> &mut Shard {
        self.data
    }
}

/// One shard's key interner: one owned [`SessionKey`] per distinct
/// session, local-index assignment by stable hash with explicit collision
/// chains (two distinct keys sharing an FNV-64 hash still get distinct
/// ids). Sharded by the same hash as the session shards, so cross-shard
/// requests never touch the same interner lock — the sharded design's
/// "cross-shard requests never contend" invariant holds for identity
/// resolution too.
#[derive(Default)]
struct Interner {
    by_hash: HashMap<u64, Vec<u32>>,
    keys: Vec<SessionKey>,
}

/// N shards of sessions plus their per-shard key interners. A global
/// [`SessionId`] packs `(local_index, shard)` as
/// `local * num_shards + shard`, so id→shard resolution is arithmetic,
/// not a lock.
///
/// The store also holds the node's **fleet priors**: merged cross-node
/// arm statistics per [`FleetKey`], installed by the sync plane
/// ([`super::fleet`]) and consulted exactly once per session lifetime —
/// at cold creation — to warm-start new sessions from fleet knowledge.
/// Lock order is strictly `shard → fleet_priors` (creation reads the
/// prior map under a shard write lock; installers never hold a shard
/// lock), so the two planes cannot deadlock.
pub struct ShardedStore {
    shards: Vec<ShardCell>,
    interners: Vec<RwLock<Interner>>,
    fleet_priors: RwLock<HashMap<FleetKey, FleetPrior>>,
    /// Retention applied to a fleet prior at session creation ((0, 1]).
    fleet_retain: f64,
    /// Half-life of fleet-prior counts between syncs; stale remote
    /// evidence decays instead of swamping fresh local observations.
    fleet_half_life: Duration,
    /// Sessions that were warm-started from a fleet prior.
    fleet_warm_starts: AtomicU64,
    /// Per-shard session counts, maintained at creation/insert so that
    /// `/healthz` and `/metrics` never need a shard lock (in the routed
    /// reactor the shards belong to their event loops and may not be
    /// scanned from a foreign thread at all).
    session_counts: Vec<AtomicU64>,
    /// Global bandit scratch-growth counter, folded in incrementally
    /// after tuner operations (see [`ShardedStore::note_scratch`]) for
    /// the same reason: the zero-allocation certification reads it live
    /// while event loops own the shards.
    scratch_growths: AtomicU64,
}

impl ShardedStore {
    pub fn new(shards: usize) -> ShardedStore {
        assert!(shards > 0, "need at least one shard");
        ShardedStore {
            shards: (0..shards).map(|_| ShardCell::new()).collect(),
            interners: (0..shards).map(|_| RwLock::new(Interner::default())).collect(),
            fleet_priors: RwLock::new(HashMap::new()),
            fleet_retain: 0.3,
            fleet_half_life: Duration::from_secs(600),
            fleet_warm_starts: AtomicU64::new(0),
            session_counts: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            scratch_growths: AtomicU64::new(0),
        }
    }

    /// Builder: how strongly fleet priors bias new sessions (`retain`)
    /// and how quickly an installed prior ages out (`half_life`).
    pub fn with_fleet_tuning(mut self, retain: f64, half_life: Duration) -> ShardedStore {
        assert!(retain > 0.0 && retain <= 1.0, "fleet retain out of (0,1]");
        assert!(!half_life.is_zero(), "fleet half-life must be positive");
        self.fleet_retain = retain;
        self.fleet_half_life = half_life;
        self
    }

    /// Install (replace) the merged fleet prior for one scenario. Called
    /// by the sync plane after every successful pull/push merge; never
    /// called under a shard lock (see the struct-level lock order).
    pub fn install_fleet_prior(&self, key: FleetKey, state: ArmStats) {
        let mut priors = match self.fleet_priors.write() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        priors.insert(key, FleetPrior { state, installed: Instant::now() });
    }

    /// Scenarios with an installed fleet prior.
    pub fn fleet_prior_keys(&self) -> usize {
        match self.fleet_priors.read() {
            Ok(g) => g.len(),
            Err(p) => p.into_inner().len(),
        }
    }

    /// Sessions warm-started from a fleet prior since boot.
    pub fn fleet_warm_starts(&self) -> u64 {
        self.fleet_warm_starts.load(Ordering::Relaxed)
    }

    /// The decayed fleet prior for a scenario, if one is installed and
    /// still carries weight. Counts (and sums, preserving means) are
    /// scaled by `0.5^(age / half_life)`, so a prior that stopped being
    /// refreshed — leader gone, network partitioned — fades away instead
    /// of anchoring new sessions to stale evidence forever.
    ///
    /// Arms whose decayed count falls below [`FLEET_PRIOR_MIN_COUNT`]
    /// are dropped entirely: the downstream [`ArmStats::discounted`]
    /// floors any positive count back to one whole pull, which would
    /// otherwise resurrect long-dead evidence at full strength and defeat
    /// the decay.
    pub fn fleet_prior_for(&self, key: &FleetKey, k: usize) -> Option<ArmStats> {
        let priors = match self.fleet_priors.read() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let prior = priors.get(key)?;
        if prior.state.k() != k {
            return None;
        }
        let age_s = prior.installed.elapsed().as_secs_f64();
        let w = 0.5_f64.powf(age_s / self.fleet_half_life.as_secs_f64().max(1e-9));
        if w < 1e-3 {
            return None;
        }
        let mut state = ArmStats::new(k);
        for i in 0..k {
            let c = prior.state.counts()[i] * w;
            if c >= FLEET_PRIOR_MIN_COUNT {
                state.set_arm(i, c, prior.state.tau_sum()[i] * w, prior.state.rho_sum()[i] * w);
            }
        }
        if state.total_pulls() <= 0.0 {
            return None;
        }
        Some(state)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard index owning a key hash (see [`KeyRef::hash64`]).
    pub fn shard_of_hash(&self, hash: u64) -> usize {
        (hash % self.shards.len() as u64) as usize
    }

    /// The shard index owning `key`.
    pub fn shard_of(&self, key: &SessionKey) -> usize {
        self.shard_of_hash(key.hash64())
    }

    fn global_id(&self, local: u32, shard: usize) -> SessionId {
        SessionId(local * self.num_shards() as u32 + shard as u32)
    }

    fn local_of(&self, id: SessionId) -> (usize, usize) {
        let n = self.num_shards() as u32;
        ((id.0 / n) as usize, (id.0 % n) as usize)
    }

    /// Resolve a borrowed key to its id without interning it. This is
    /// the steady-state path: one per-shard read lock and slice
    /// compares, zero allocations.
    pub fn lookup(&self, key: &KeyRef<'_>, hash: u64) -> Option<SessionId> {
        let shard = self.shard_of_hash(hash);
        let interner = match self.interners[shard].read() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        interner
            .by_hash
            .get(&hash)?
            .iter()
            .copied()
            .find(|&local| key.matches(&interner.keys[local as usize]))
            .map(|local| self.global_id(local, shard))
    }

    /// Resolve-or-assign an id for a borrowed key. Allocation (the owned
    /// `SessionKey` clone) happens exactly once per session lifetime,
    /// under the key's own shard's write lock — interning a new session
    /// never blocks requests for other shards.
    pub fn intern(&self, key: &KeyRef<'_>, hash: u64) -> SessionId {
        if let Some(id) = self.lookup(key, hash) {
            return id;
        }
        let shard = self.shard_of_hash(hash);
        let mut interner = match self.interners[shard].write() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        // Double-check under the write lock (another thread may have
        // interned the same key between our read and write).
        if let Some(local) = interner.by_hash.get(&hash).and_then(|ids| {
            ids.iter().copied().find(|&local| key.matches(&interner.keys[local as usize]))
        }) {
            return self.global_id(local, shard);
        }
        let local = interner.keys.len() as u32;
        interner.keys.push(key.to_key());
        interner.by_hash.entry(hash).or_default().push(local);
        self.global_id(local, shard)
    }

    /// The owned key for an id (cold paths: session creation, tests).
    pub fn key_of(&self, id: SessionId) -> Option<SessionKey> {
        let (local, shard) = self.local_of(id);
        let interner = match self.interners[shard].read() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        interner.keys.get(local).cloned()
    }

    /// Shared-read lock on shard `i` (locked discipline: blocking
    /// transport, boot restore, shutdown checkpoint, tests). Poisoned
    /// locks are recovered: a panicking request handler must not take
    /// the whole shard down with it.
    pub fn read_shard(&self, i: usize) -> ShardReadGuard<'_> {
        let cell = &self.shards[i];
        let lock = match cell.lock.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        // Safety: the read lock is held for the guard's lifetime, and
        // every mutating accessor in the locked discipline takes the
        // write lock. Owned (lockless) mutation never overlaps with the
        // locked discipline in time — see [`ShardCell`].
        ShardReadGuard { data: unsafe { &*cell.data.get() }, _lock: lock }
    }

    /// Exclusive lock on shard `i` (locked discipline) — suggest's
    /// `select()` and the batched report drain when the shared
    /// (non-routed) data plane is active.
    pub fn write_shard(&self, i: usize) -> ShardWriteGuard<'_> {
        let cell = &self.shards[i];
        let lock = match cell.lock.write() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        // Safety: as for `read_shard`, with the exclusive lock held.
        ShardWriteGuard { data: unsafe { &mut *cell.data.get() }, _lock: lock }
    }

    /// Unsynchronized exclusive access to shard `i` — the shared-nothing
    /// hot path. Zero lock operations in release builds; in debug builds
    /// an assertion proves the suggest/report path could never have
    /// parked here (the lock must be observably free).
    ///
    /// # Safety
    ///
    /// The caller must be the unique thread accessing shard `i` for the
    /// lifetime of the returned reference: in practice, the event loop
    /// that owns the shard under the routed data plane's ownership map,
    /// between loop start and loop join, with every cross-cutting
    /// consumer going through the owner's mailbox.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn owned_shard_mut(&self, i: usize) -> &mut Shard {
        let cell = &self.shards[i];
        debug_assert!(
            cell.lock.try_write().is_ok(),
            "owned shard {i} accessed while its lock is held — the hot path would have parked"
        );
        unsafe { &mut *cell.data.get() }
    }

    /// Fetch a session in a locked shard, creating one on first contact.
    /// Returns the session and whether it was created. A session's
    /// `alpha`/`beta` are fixed at creation; later requests with
    /// different weights reuse the existing tuner (re-keying by weights
    /// would fragment state).
    ///
    /// Creation is not always cold: when the sync plane has installed a
    /// fleet prior for the session's `(app, device, policy)` scenario,
    /// the new tuner warm-starts from it (decayed by prior age, then
    /// discounted by `fleet_retain`) instead of exploring from scratch —
    /// the cross-node transfer payoff.
    pub fn get_or_create<'s>(
        &self,
        shard: &'s mut Shard,
        id: SessionId,
        alpha: f64,
        beta: f64,
        k: usize,
    ) -> Result<(&'s mut Session, bool), String> {
        use std::collections::hash_map::Entry;
        match shard.sessions.entry(id.0) {
            Entry::Occupied(e) => Ok((e.into_mut(), false)),
            Entry::Vacant(v) => {
                let key = self
                    .key_of(id)
                    .ok_or_else(|| format!("unknown session id {}", id.0))?;
                let fleet_key = FleetKey {
                    app: key.app,
                    device: key.device,
                    policy: key.policy,
                };
                let prior = self.fleet_prior_for(&fleet_key, k);
                let (prior_ref, retain) = match &prior {
                    Some(state) => (Some(state), self.fleet_retain),
                    None => (None, 1.0),
                };
                let tuner =
                    Tuner::build(key.policy, k, alpha, beta, key.hash64(), prior_ref, retain)?;
                // Record what the tuner starts from (post-discount,
                // tuner-space) so the sync plane can export deltas only.
                // A prior can fail to apply — e.g. a sparse fleet prior
                // with zero overlap with a subset session's candidates —
                // in which case this is a cold start, not a warm one.
                let applied = prior.is_some() && tuner.total_pulls() > 0.0;
                let fleet_baseline = if applied {
                    self.fleet_warm_starts.fetch_add(1, Ordering::Relaxed);
                    Some(tuner.stats().clone())
                } else {
                    None
                };
                let session = Session {
                    key,
                    alpha,
                    beta,
                    tuner,
                    fleet_baseline,
                    suggests: 0,
                    reports: 0,
                    seq_window: SeqWindow::default(),
                    scratch_growths_seen: 0,
                };
                let (_, shard_i) = self.local_of(id);
                self.session_counts[shard_i].fetch_add(1, Ordering::Relaxed);
                Ok((v.insert(session), true))
            }
        }
    }

    /// Total sessions across all shards. Lock-free (atomic counters
    /// maintained at creation), so `/healthz` and `/metrics` can read it
    /// while event loops own the shards.
    pub fn session_count(&self) -> usize {
        self.session_counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed) as usize)
            .sum()
    }

    /// Sessions living on shard `i` (lock-free; drives the per-loop
    /// ownership gauges in `/metrics`).
    pub fn shard_session_count(&self, i: usize) -> usize {
        self.session_counts[i].load(Ordering::Relaxed) as usize
    }

    /// Total scratch-buffer growth events across every session's policy.
    /// Flat after warm-up: the bandit-core half of the serve layer's
    /// zero-allocation contract, asserted end-to-end by
    /// `rust/tests/serve_hotpath.rs`. Maintained incrementally (see
    /// [`ShardedStore::note_scratch`]) so reading it never needs a shard
    /// lock.
    pub fn scratch_growth_total(&self) -> u64 {
        self.scratch_growths.load(Ordering::Relaxed)
    }

    /// Fold a session's unobserved scratch growths into the global
    /// counter. Called after tuner operations that can grow scoring
    /// scratch (select paths); zero atomic writes in the steady state
    /// where nothing grew.
    pub fn note_scratch(&self, session: &mut Session) {
        let now = session.tuner.policy().scratch_growths();
        let delta = now.saturating_sub(session.scratch_growths_seen);
        if delta > 0 {
            self.scratch_growths.fetch_add(delta, Ordering::Relaxed);
            session.scratch_growths_seen = now;
        }
    }

    /// Insert a fully built session (checkpoint restore). Existing live
    /// sessions win over checkpointed ones.
    pub fn insert_session(&self, session: Session) {
        let hash = session.key.hash64();
        let id = self.intern(&session.key.as_ref(), hash);
        let i = self.shard_of_hash(hash);
        let mut shard = self.write_shard(i);
        if let std::collections::hash_map::Entry::Vacant(v) = shard.sessions.entry(id.0) {
            v.insert(session);
            self.session_counts[i].fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Immutable per-app lookups shared by every serve component: the four app
/// models are built once, then only read (`AppModel` is `Send + Sync`).
pub struct AppsCache {
    models: Vec<Box<dyn AppModel>>,
}

impl AppsCache {
    pub fn new() -> AppsCache {
        AppsCache {
            models: AppKind::all().iter().map(|&k| apps::build(k)).collect(),
        }
    }

    fn idx(kind: AppKind) -> usize {
        match kind {
            AppKind::Lulesh => 0,
            AppKind::Kripke => 1,
            AppKind::Clomp => 2,
            AppKind::Hypre => 3,
        }
    }

    /// The app model.
    pub fn model(&self, kind: AppKind) -> &dyn AppModel {
        self.models[Self::idx(kind)].as_ref()
    }

    /// Arm count of the app's Table II space.
    pub fn arms(&self, kind: AppKind) -> usize {
        self.model(kind).space().len()
    }

    /// Human-readable rendering of configuration `arm`.
    pub fn describe(&self, kind: AppKind, arm: usize) -> String {
        self.model(kind).space().describe(arm)
    }

    /// As [`Self::describe`], appending into a reusable buffer (the
    /// suggest/best hot paths stream this through `JsonWriter` without
    /// allocating a `String` per request).
    pub fn describe_into(&self, kind: AppKind, arm: usize, out: &mut String) {
        self.model(kind).space().describe_into(arm, out);
    }
}

impl Default for AppsCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(client: &str, app: AppKind, policy: PolicyKind) -> SessionKey {
        SessionKey {
            client_id: client.to_string(),
            app,
            device: PowerMode::Maxn,
            policy,
        }
    }

    #[test]
    fn hash_is_stable_and_field_sensitive() {
        let a = key("alice", AppKind::Clomp, PolicyKind::Ucb);
        assert_eq!(a.hash64(), a.clone().hash64());
        let b = key("alicf", AppKind::Clomp, PolicyKind::Ucb);
        assert_ne!(a.hash64(), b.hash64());
        let c = key("alice", AppKind::Kripke, PolicyKind::Ucb);
        assert_ne!(a.hash64(), c.hash64());
        let d = key("alice", AppKind::Clomp, PolicyKind::Thompson);
        assert_ne!(a.hash64(), d.hash64());
    }

    #[test]
    fn sessions_spread_across_shards() {
        let store = ShardedStore::new(8);
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            let k = key(&format!("client-{i}"), AppKind::Clomp, PolicyKind::Ucb);
            seen.insert(store.shard_of(&k));
        }
        assert!(seen.len() >= 4, "only {} shards used", seen.len());
    }

    #[test]
    fn get_or_create_then_select_and_observe() {
        let store = ShardedStore::new(4);
        let k = key("c1", AppKind::Clomp, PolicyKind::Ucb);
        let hash = k.hash64();
        let id = store.intern(&k.as_ref(), hash);
        let i = store.shard_of_hash(hash);
        let mut shard = store.write_shard(i);
        let (s, created) = store.get_or_create(&mut shard, id, 0.8, 0.2, 125).unwrap();
        assert!(created);
        let arm = s.tuner.select();
        assert!(arm < 125);
        s.tuner.observe(arm, 1.0, 5.0).unwrap();
        assert_eq!(s.tuner.total_pulls(), 1.0);
        let (_, created_again) = store.get_or_create(&mut shard, id, 0.8, 0.2, 125).unwrap();
        assert!(!created_again);
        drop(shard);
        // The read path sees the session without an exclusive lock.
        let rshard = store.read_shard(i);
        assert!(rshard.sessions.contains_key(&id.0));
    }

    #[test]
    fn interner_is_idempotent_and_clone_free_on_lookup() {
        let store = ShardedStore::new(2);
        let k = key("alice", AppKind::Clomp, PolicyKind::Ucb);
        let hash = k.hash64();
        assert_eq!(store.lookup(&k.as_ref(), hash), None);
        let id = store.intern(&k.as_ref(), hash);
        assert_eq!(store.intern(&k.as_ref(), hash), id);
        assert_eq!(store.lookup(&k.as_ref(), hash), Some(id));
        // Borrowed lookups resolve the same id with no owned key in hand.
        let borrowed = KeyRef {
            client_id: "alice",
            app: AppKind::Clomp,
            device: PowerMode::Maxn,
            policy: PolicyKind::Ucb,
        };
        assert_eq!(borrowed.hash64(), hash);
        assert_eq!(store.lookup(&borrowed, hash), Some(id));
        // A different key gets a different id.
        let k2 = key("bob", AppKind::Clomp, PolicyKind::Ucb);
        let id2 = store.intern(&k2.as_ref(), k2.hash64());
        assert_ne!(id, id2);
        assert_eq!(store.key_of(id).as_ref(), Some(&k));
        assert_eq!(store.key_of(id2).as_ref(), Some(&k2));
    }

    #[test]
    fn concurrent_readers_share_a_shard() {
        use std::sync::Arc;
        let store = Arc::new(ShardedStore::new(1));
        let k = key("reader", AppKind::Clomp, PolicyKind::Ucb);
        let id = store.intern(&k.as_ref(), k.hash64());
        {
            let mut shard = store.write_shard(0);
            store.get_or_create(&mut shard, id, 0.8, 0.2, 125).unwrap();
        }
        // Hold a read guard while other threads also read: RwLock must
        // admit them all (a Mutex here would deadlock nobody but would
        // serialize; this documents the shared-read contract compiles
        // and runs).
        let g1 = store.read_shard(0);
        let store2 = store.clone();
        let t = std::thread::spawn(move || {
            let g2 = store2.read_shard(0);
            g2.sessions.len()
        });
        assert_eq!(t.join().unwrap(), 1);
        assert_eq!(g1.sessions.len(), 1);
    }

    #[test]
    fn seq_window_absorbs_duplicates_and_reorders() {
        let mut w = SeqWindow::default();
        // First-ever seq initializes the window.
        assert!(w.accept(10));
        assert!(!w.accept(10), "duplicate of the head");
        // In-window reorder: older seqs are accepted exactly once each.
        assert!(w.accept(8));
        assert!(w.accept(9));
        assert!(!w.accept(8));
        assert!(!w.accept(9));
        // Forward progress.
        assert!(w.accept(11));
        assert_eq!(w.head(), Some(11));
        assert!(!w.accept(11));
        // A gap leaves the skipped seqs acceptable later (reorder), and
        // everything older than the window is absorbed as a duplicate.
        assert!(w.accept(11 + SEQ_WINDOW));
        assert!(w.accept(11 + SEQ_WINDOW - 1), "in-window straggler");
        assert!(!w.accept(11), "older than the window: absorbed");
        assert!(!w.accept(0), "far past: absorbed");
        // A jump much larger than the window clears the bitmap cleanly.
        assert!(w.accept(10 * SEQ_WINDOW));
        assert!(!w.accept(10 * SEQ_WINDOW));
        assert!(w.accept(10 * SEQ_WINDOW - 1));
    }

    #[test]
    fn seq_window_is_fresh_per_session() {
        let store = ShardedStore::new(1);
        let k = key("seq", AppKind::Clomp, PolicyKind::Ucb);
        let id = store.intern(&k.as_ref(), k.hash64());
        let mut shard = store.write_shard(0);
        let (s, created) = store.get_or_create(&mut shard, id, 0.8, 0.2, 125).unwrap();
        assert!(created);
        assert_eq!(s.seq_window.head(), None);
        assert!(s.seq_window.accept(1));
        assert!(!s.seq_window.accept(1));
    }

    #[test]
    fn observe_rejects_bad_input_without_panic() {
        let mut t = Tuner::build(PolicyKind::Ucb, 8, 1.0, 0.0, 1, None, 1.0).unwrap();
        assert!(t.observe(8, 1.0, 1.0).is_err());
        assert!(t.observe(0, f64::NAN, 1.0).is_err());
        assert!(t.observe(0, -1.0, 1.0).is_err());
        assert!(t.observe(0, 1.0, -1.0).is_err());
        assert!(t.observe(0, 1.0, 1.0).is_ok());
    }

    #[test]
    fn subset_rejects_non_candidate_arms() {
        let mut t =
            Tuner::build(PolicyKind::Subset, 92_160, 1.0, 0.0, 99, None, 1.0).unwrap();
        let arm = t.select();
        assert!(t.observe(arm, 1.0, 1.0).is_ok());
        // Find a non-candidate arm: with 1024 of 92160 chosen, scanning a
        // few indices is guaranteed to hit one.
        let miss = (0..92_160)
            .find(|&a| t.observe(a, 1.0, 1.0).is_err())
            .expect("some arm outside the subset");
        assert!(miss < 92_160);
    }

    #[test]
    fn default_policy_scales_with_space() {
        assert_eq!(PolicyKind::default_for(216), PolicyKind::Ucb);
        assert_eq!(PolicyKind::default_for(92_160), PolicyKind::Subset);
    }

    #[test]
    fn policy_kind_parses_every_variant() {
        for kind in [
            PolicyKind::Ucb,
            PolicyKind::SwUcb,
            PolicyKind::Thompson,
            PolicyKind::Epsilon,
            PolicyKind::Subset,
        ] {
            assert_eq!(kind.name().parse::<PolicyKind>().unwrap(), kind);
        }
        assert!("doom".parse::<PolicyKind>().is_err());
    }

    #[test]
    fn warm_start_preserves_means() {
        let mut state = ArmStats::new(16);
        for arm in 0..16 {
            for _ in 0..10 {
                state.observe(arm, 1.0 + arm as f64, 5.0);
            }
        }
        // The unified warm-start path behaves identically for every
        // same-space policy, epsilon included (the satellite fix).
        for kind in [
            PolicyKind::Ucb,
            PolicyKind::SwUcb,
            PolicyKind::Thompson,
            PolicyKind::Epsilon,
        ] {
            let t = Tuner::build(kind, 16, 1.0, 0.0, 7, Some(&state), 0.5).unwrap();
            let (mt, _) = t.mean_of(3).unwrap();
            assert!((mt - 4.0).abs() < 1e-9, "{}", kind.name());
            assert!(t.total_pulls() > 0.0, "{}", kind.name());
        }
    }

    #[test]
    fn warm_start_arm_mismatch_is_error() {
        let state = ArmStats::new(8);
        for kind in [
            PolicyKind::Ucb,
            PolicyKind::SwUcb,
            PolicyKind::Thompson,
            PolicyKind::Epsilon,
        ] {
            assert!(
                Tuner::build(kind, 16, 1.0, 0.0, 7, Some(&state), 0.5).is_err(),
                "{}",
                kind.name()
            );
        }
    }

    fn fleet_key(app: AppKind, policy: PolicyKind) -> FleetKey {
        FleetKey { app, device: PowerMode::Maxn, policy }
    }

    /// A full-space prior shaped like a converged campaign: every arm
    /// pulled (so a warm start skips the init sweep), the `best` arm both
    /// fastest and by far the most pulled (so Eq. 4 transfers too).
    fn full_prior(k: usize, best: usize) -> ArmStats {
        let mut s = ArmStats::new(k);
        for arm in 0..k {
            let (t, pulls) = if arm == best { (0.3, 40) } else { (2.0, 4) };
            for _ in 0..pulls {
                s.observe(arm, t, 5.0);
            }
        }
        s
    }

    #[test]
    fn fleet_prior_warm_starts_new_sessions() {
        let store = ShardedStore::new(2).with_fleet_tuning(0.5, Duration::from_secs(600));
        store.install_fleet_prior(
            fleet_key(AppKind::Clomp, PolicyKind::Ucb),
            full_prior(125, 77),
        );
        assert_eq!(store.fleet_prior_keys(), 1);

        let k = key("fresh", AppKind::Clomp, PolicyKind::Ucb);
        let id = store.intern(&k.as_ref(), k.hash64());
        let i = store.shard_of_hash(k.hash64());
        let mut shard = store.write_shard(i);
        let (s, created) = store.get_or_create(&mut shard, id, 1.0, 0.0, 125).unwrap();
        assert!(created);
        // Every arm carries prior counts: no init sweep, Eq. 4 answers
        // the fleet's best arm before a single local pull.
        assert!(s.tuner.total_pulls() > 0.0);
        assert_eq!(s.tuner.most_selected(), 77);
        let (mean_t, _) = s.tuner.mean_of(77).unwrap();
        assert!((mean_t - 0.3).abs() < 1e-9, "prior mean drifted: {mean_t}");
        drop(shard);
        assert_eq!(store.fleet_warm_starts(), 1);

        // A scenario without a prior still cold-starts.
        let k2 = key("fresh", AppKind::Kripke, PolicyKind::Ucb);
        let id2 = store.intern(&k2.as_ref(), k2.hash64());
        let i2 = store.shard_of_hash(k2.hash64());
        let mut shard2 = store.write_shard(i2);
        let (s2, _) = store.get_or_create(&mut shard2, id2, 1.0, 0.0, 216).unwrap();
        assert_eq!(s2.tuner.total_pulls(), 0.0);
        drop(shard2);
        assert_eq!(store.fleet_warm_starts(), 1);
    }

    #[test]
    fn fleet_prior_decays_with_age() {
        // A ~zero half-life makes any installed prior immediately stale:
        // it must be ignored, not applied at full weight.
        let store = ShardedStore::new(1).with_fleet_tuning(0.5, Duration::from_millis(1));
        let fk = fleet_key(AppKind::Clomp, PolicyKind::Ucb);
        store.install_fleet_prior(fk, full_prior(125, 7));
        std::thread::sleep(Duration::from_millis(30));
        assert!(store.fleet_prior_for(&fk, 125).is_none(), "stale prior survived");

        // A long half-life keeps it essentially intact, means preserved.
        let store = ShardedStore::new(1).with_fleet_tuning(0.5, Duration::from_secs(3600));
        store.install_fleet_prior(fk, full_prior(125, 7));
        let got = store.fleet_prior_for(&fk, 125).unwrap();
        assert!((got.mean_tau()[7] - 0.3).abs() < 1e-9);
        assert!(got.counts()[7] <= 40.0 + 1e-9, "decay must never grow counts");
        // Arm-count mismatch (wrong app space) is refused.
        assert!(store.fleet_prior_for(&fk, 216).is_none());
    }

    #[test]
    fn fleet_prior_projects_onto_subset_sessions() {
        let store = ShardedStore::new(1).with_fleet_tuning(0.5, Duration::from_secs(3600));
        // Full-space Hypre prior: every arm pulled once, arm `fast` much
        // faster. The subset session sees it through its own candidates.
        let mut prior = ArmStats::new(92_160);
        for arm in 0..92_160 {
            prior.observe(arm, 2.0, 5.0);
        }
        store.install_fleet_prior(fleet_key(AppKind::Hypre, PolicyKind::Subset), prior);

        let k = key("hy", AppKind::Hypre, PolicyKind::Subset);
        let id = store.intern(&k.as_ref(), k.hash64());
        let mut shard = store.write_shard(0);
        let (s, created) = store.get_or_create(&mut shard, id, 1.0, 0.0, 92_160).unwrap();
        assert!(created);
        // All candidates carry projected prior pulls.
        assert!(s.tuner.total_pulls() > 0.0, "subset projection lost the prior");
        let arm = s.tuner.select();
        assert!(arm < 92_160);
        drop(shard);
        assert_eq!(store.fleet_warm_starts(), 1);
    }

    #[test]
    fn subset_build_accepts_full_space_prior() {
        // Direct Tuner::build coverage for the projection path: a prior
        // sized to the full space (fleet) and one sized to the subset
        // (checkpoint) both build; other sizes are errors.
        let k = 92_160;
        let mut full = ArmStats::new(k);
        for arm in 0..k {
            full.observe(arm, 1.0, 5.0);
        }
        let t = Tuner::build(PolicyKind::Subset, k, 1.0, 0.0, 9, Some(&full), 0.5).unwrap();
        assert!(t.total_pulls() > 0.0);
        let sub = ArmStats::new(SUBSET_ARMS);
        assert!(Tuner::build(PolicyKind::Subset, k, 1.0, 0.0, 9, Some(&sub), 0.5).is_ok());
        let bad = ArmStats::new(17);
        assert!(Tuner::build(PolicyKind::Subset, k, 1.0, 0.0, 9, Some(&bad), 0.5).is_err());
    }

    #[test]
    fn apps_cache_matches_table2() {
        let cache = AppsCache::new();
        assert_eq!(cache.arms(AppKind::Kripke), 216);
        assert_eq!(cache.arms(AppKind::Hypre), 92_160);
        assert!(!cache.describe(AppKind::Clomp, 0).is_empty());
    }
}
