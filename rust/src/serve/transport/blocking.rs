//! The legacy blocking transport: one accept thread feeds accepted
//! connections into a bounded channel drained by a fixed pool of worker
//! threads (the bound is the backpressure — a flood of connections
//! blocks in `accept`, not in unbounded memory). Each worker owns one
//! connection at a time, so the pool size bounds the number of
//! concurrent keep-alive clients.
//!
//! Kept as the differential baseline for the event-driven reactor: both
//! backends share the parser, the reusable buffers, and the
//! growth-accounting seams in [`super`], and the differential suite
//! asserts bit-identical responses and alloc-event parity between them.

use super::parser::{self, ConnBuf, Parsed, TryParse};
use super::{
    assemble_frame, dispatch, ConnCtx, HttpHandler, Request, ResponseBuf, TransportOptions,
    TransportStats,
};
use anyhow::{Context as _, Result};
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Idle keep-alive connections wake this often to check for shutdown.
const READ_TIMEOUT: Duration = Duration::from_millis(500);

/// Outcome of waiting for one request on a connection.
enum ReadOutcome {
    Request(Parsed),
    /// Peer closed cleanly between requests.
    Closed,
    /// Idle read timeout (connection still healthy; buffered partial
    /// bytes are preserved for the next attempt).
    Idle,
    /// Protocol violation; connection must be dropped after `status`.
    Malformed(u16, &'static str),
}

/// A running blocking server: accept thread + fixed worker pool.
pub struct BlockingServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    stats: Arc<TransportStats>,
    accept_thread: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
}

impl BlockingServer {
    /// Start serving `listener` with `opts.threads` handler threads.
    pub fn start(
        listener: TcpListener,
        handler: HttpHandler,
        opts: TransportOptions,
    ) -> Result<BlockingServer> {
        let workers = opts.threads;
        assert!(workers > 0);
        let stats = opts.stats;
        let chaos = opts.chaos;
        let addr = listener.local_addr().context("reading bound address")?;
        let shutdown = Arc::new(AtomicBool::new(false));

        // Bounded hand-off: a connection flood blocks the accept thread
        // (kernel backlog) instead of queueing unboundedly in memory.
        let (tx, rx): (SyncSender<TcpStream>, Receiver<TcpStream>) = sync_channel(workers * 4);
        let rx = Arc::new(Mutex::new(rx));

        let mut pool = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = rx.clone();
            let handler = handler.clone();
            let shutdown = shutdown.clone();
            let stats = stats.clone();
            pool.push(std::thread::spawn(move || {
                // Connection-lifetime buffers (see module docs). They are
                // per-worker so a long-lived keep-alive client reuses the
                // same memory for every request it sends.
                let mut conn = ConnBuf::new();
                let mut resp = ResponseBuf::new();
                let mut frame: Vec<u8> = Vec::with_capacity(1024);
                // Degenerate single-owner mode: every worker reports loop
                // index 0, so the service's shared data plane applies.
                let mut ctx = ConnCtx::new(0);
                loop {
                    let stream = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            Err(p) => p.into_inner(),
                        };
                        guard.recv()
                    };
                    match stream {
                        Ok(s) => {
                            // Reset per-connection state, keep capacity.
                            conn.reset();
                            ctx.reset(0);
                            handle_connection(
                                s, &handler, &shutdown, &stats, &mut conn, &mut ctx, &mut resp,
                                &mut frame,
                            );
                        }
                        Err(_) => return, // accept thread gone: shutdown
                    }
                }
            }));
        }

        let accept_thread = {
            let shutdown = shutdown.clone();
            let stats = stats.clone();
            std::thread::spawn(move || {
                // `tx` lives in this thread; dropping it on exit releases
                // the worker pool.
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    let Ok(stream) = conn else { continue };
                    if let Some(c) = &chaos {
                        if c.accept_drop() {
                            // Close before a byte is served; the client
                            // sees a reset, as on a flaky edge link.
                            drop(stream);
                            continue;
                        }
                    }
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
                    stats.connections.fetch_add(1, Ordering::Relaxed);
                    if tx.send(stream).is_err() {
                        return;
                    }
                }
            })
        };

        Ok(BlockingServer { addr, shutdown, stats, accept_thread, workers: pool })
    }

    /// The bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Transport counters (connections, requests, alloc events).
    pub fn stats(&self) -> Arc<TransportStats> {
        self.stats.clone()
    }

    /// Stop accepting, close workers, join all threads.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the accept thread out of its blocking accept.
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept_thread.join();
        for w in self.workers {
            let _ = w.join();
        }
    }

    /// Block until the server exits on its own (never, in practice).
    pub fn join(self) {
        let _ = self.accept_thread.join();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Drive the buffer until one complete request is available (or a
/// terminal outcome). Pipelined requests already in the buffer parse
/// without touching the socket.
fn read_request(conn: &mut ConnBuf, stream: &mut TcpStream, stats: &TransportStats) -> ReadOutcome {
    loop {
        if conn.len() > 0 {
            match parser::try_parse(conn.window()) {
                TryParse::Complete(p) => return ReadOutcome::Request(p),
                TryParse::Bad(status, msg) => return ReadOutcome::Malformed(status, msg),
                TryParse::NeedMore => {
                    // A partial request must complete within its deadline
                    // — a trickling client (slow-loris) cannot pin a pool
                    // worker indefinitely.
                    if conn.deadline_exceeded() {
                        return ReadOutcome::Malformed(408, "request timeout");
                    }
                }
            }
        }
        match conn.fill(stream, stats) {
            Ok(0) => {
                return if conn.len() == 0 {
                    ReadOutcome::Closed
                } else {
                    ReadOutcome::Malformed(400, "eof mid-request")
                };
            }
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Partial bytes stay buffered; surface Idle so the worker
                // can check for shutdown and resume exactly where the
                // stream paused (no desync, unlike a line-based parser).
                return ReadOutcome::Idle;
            }
            Err(_) => return ReadOutcome::Closed,
        }
    }
}

/// Assemble and write one response as a single segment (one syscall on
/// the hot path).
fn write_response(
    stream: &mut TcpStream,
    resp: &ResponseBuf,
    keep_alive: bool,
    frame: &mut Vec<u8>,
    stats: &TransportStats,
) -> std::io::Result<()> {
    use std::io::Write as _;
    assemble_frame(frame, resp, keep_alive, stats);
    stream.write_all(frame)?;
    stream.flush()
}

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    mut stream: TcpStream,
    handler: &HttpHandler,
    shutdown: &AtomicBool,
    stats: &TransportStats,
    conn: &mut ConnBuf,
    ctx: &mut ConnCtx,
    resp: &mut ResponseBuf,
    frame: &mut Vec<u8>,
) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match read_request(conn, &mut stream, stats) {
            ReadOutcome::Request(p) => {
                stats.requests.fetch_add(1, Ordering::Relaxed);
                let close = {
                    // Borrow the parsed slices out of the buffer window.
                    let base = conn.start;
                    let data = &conn.data[base..conn.filled];
                    // The head was validated as UTF-8 by try_parse.
                    let req = Request {
                        method: std::str::from_utf8(&data[p.method.clone()]).unwrap_or(""),
                        path: std::str::from_utf8(&data[p.path.clone()]).unwrap_or(""),
                        query: std::str::from_utf8(&data[p.query.clone()]).unwrap_or(""),
                        body: &data[p.body.clone()],
                        close: p.close,
                    };
                    dispatch(handler, &req, ctx, resp, stats);
                    req.close
                };
                if write_response(&mut stream, resp, !close, frame, stats).is_err() || close {
                    return;
                }
                conn.consume(p.total_len);
            }
            ReadOutcome::Idle => continue,
            ReadOutcome::Closed => return,
            ReadOutcome::Malformed(status, msg) => {
                if status == 431 {
                    stats.rejected_431.fetch_add(1, Ordering::Relaxed);
                }
                resp.reset();
                resp.error(status, msg);
                let _ = write_response(&mut stream, resp, false, frame, stats);
                // Lingering close: drain (bounded) whatever the client is
                // still sending, so closing the socket with unread bytes
                // cannot RST the error response away before the client
                // reads it.
                let deadline = Instant::now() + parser::LINGER;
                let mut scratch = [0u8; 1024];
                while Instant::now() < deadline {
                    match stream.read(&mut scratch) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {}
                    }
                }
                return;
            }
        }
    }
}
