//! HTTP/1.1 transports for the tuning service — allocation-free in
//! steady state, with two interchangeable backends behind one seam.
//!
//! * [`reactor`] (the default) — a readiness-driven event loop: N
//!   event-loop threads, each owning a poller ([`poller::Poller`]: epoll
//!   on Linux, `poll(2)` elsewhere), a slab of per-connection state
//!   machines (`Reading → Handling → Writing → KeepAlive`), and a timer
//!   wheel enforcing the 408 slow-loris deadline. Accepted sockets are
//!   distributed round-robin across loops; a write that would block
//!   parks the connection on `EPOLLOUT` instead of pinning a thread, so
//!   one node holds 10k+ mostly-idle keep-alive clients.
//! * [`blocking`] (legacy) — the accept-thread + bounded-channel +
//!   fixed-worker-pool transport, kept as the differential baseline:
//!   both backends must serve bit-identical responses and count
//!   identical buffer-growth events.
//!
//! ## Buffer lifecycle (the zero-allocation contract)
//!
//! Three reusable buffers carry every request: a per-connection **read
//! buffer** ([`parser::ConnBuf`]) the slice parser works in, a
//! **response buffer** ([`ResponseBuf`]) the handler serializes into,
//! and a **frame buffer** assembling head + body for a single write.
//! In the blocking pool the response/frame buffers are per-worker; in
//! the reactor they are per-event-loop (a loop handles one request at a
//! time), as is the batch arena. All growth is counted in
//! [`TransportStats::alloc_events`] by the shared buffer/dispatch code
//! in this module — `alloc_events` staying flat under steady load *is*
//! the zero-allocation property, and the tests assert exactly that.

pub mod blocking;
pub mod parser;
#[cfg(unix)]
pub mod poller;
#[cfg(unix)]
pub mod reactor;
#[cfg(test)]
mod server_tests;

pub use parser::{MAX_BODY_BYTES, MAX_HEADER_BYTES, MAX_HEADERS};
pub(crate) use parser::find_subsequence;

use crate::obs::Recorder;
use crate::util::json::JsonWriter;
use anyhow::Result;
use std::borrow::Cow;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Transport-level counters, shared by every worker/event loop of one
/// server. `alloc_events` is the serve hot path's allocation proxy: it
/// counts buffer growth in the HTTP + JSON layers (read buffer, response
/// body, frame scratch), so a flat value under steady load certifies the
/// request path performs zero heap allocations in those layers.
#[derive(Default)]
pub struct TransportStats {
    /// Connections accepted.
    pub connections: AtomicU64,
    /// Requests parsed and dispatched.
    pub requests: AtomicU64,
    /// Buffer growth events in the HTTP+JSON layers (see above).
    pub alloc_events: AtomicU64,
    /// Requests rejected with 431 (header limits).
    pub rejected_431: AtomicU64,
    /// Event loops serving this transport (gauge; 0 = blocking pool).
    pub event_loops: AtomicU64,
    /// Poller wakeups (`epoll_wait`/`poll` returns) across all loops.
    pub wakeups: AtomicU64,
    /// Currently open connections (gauge; reactor only).
    pub conns_open: AtomicU64,
    /// Writes that would have blocked and parked the connection on
    /// `EPOLLOUT` instead (write backpressure).
    pub write_backpressure: AtomicU64,
    /// Connections re-homed to their owning event loop by the routed
    /// reactor (shared-nothing mode). Each count is one connection
    /// migration, not one request — steady-state keep-alive traffic
    /// forwards once and then stays local.
    pub forwarded: AtomicU64,
    /// Requests whose `(shard, session)` resolution was served from the
    /// per-connection key cache, skipping re-hash + interner lookup.
    pub key_cache_hits: AtomicU64,
}

impl TransportStats {
    pub(crate) fn note_alloc(&self) {
        self.alloc_events.fetch_add(1, Ordering::Relaxed);
    }
}

/// A parsed HTTP request, borrowing from the connection's read buffer.
#[derive(Debug)]
pub struct Request<'a> {
    pub method: &'a str,
    /// Path without the query string, e.g. `/v1/suggest` (undecoded).
    pub path: &'a str,
    /// Raw query string after `?` (may be empty; decode via
    /// [`Request::query_get`]).
    pub query: &'a str,
    pub body: &'a [u8],
    /// Client asked for the connection to be closed after this response.
    pub close: bool,
}

impl<'a> Request<'a> {
    /// Look up and percent-decode one query parameter. Borrows from the
    /// request unless the value actually contains `%`/`+` escapes.
    /// Values that decode to invalid UTF-8 are rejected (`None`) rather
    /// than lossy-decoded — deterministic for the caller, and a malformed
    /// parameter can never impersonate a different (valid) string.
    pub fn query_get(&self, name: &str) -> Option<Cow<'a, str>> {
        query_get(self.query, name)
    }
}

/// Cached `(shard, SessionId)` resolution for the session key most
/// recently seen on a connection. Keep-alive clients (the loadgen steady
/// state) send the same key on every request; matching the parsed fields
/// against this entry lets the handler skip the FNV re-hash and the
/// interner lookup entirely. Invalidation is by value: any field
/// mismatch falls back to the full resolve path and overwrites the
/// entry in place (`client_id` reuses its allocation).
#[derive(Debug)]
pub struct KeyCacheEntry {
    pub client_id: String,
    pub app: crate::apps::AppKind,
    pub device: crate::device::PowerMode,
    pub policy: super::store::PolicyKind,
    /// FNV-1a hash of the full session key (stable across requests).
    pub hash: u64,
    /// Shard index derived from `hash`.
    pub shard: u32,
    pub id: super::store::SessionId,
}

/// Per-connection dispatch context, owned by the transport and handed to
/// the handler alongside each request. Carries which event loop is
/// driving the connection (0 on the blocking pool) and the keyed-session
/// cache. Travels with the connection when the routed reactor re-homes
/// it to its owning loop.
#[derive(Debug)]
pub struct ConnCtx {
    /// Index of the event loop currently driving this connection.
    pub loop_idx: usize,
    /// Last resolved session key, if any request on this connection
    /// carried one.
    pub key: Option<KeyCacheEntry>,
}

impl ConnCtx {
    pub fn new(loop_idx: usize) -> ConnCtx {
        ConnCtx { loop_idx, key: None }
    }

    /// Clear for reuse by the next connection (keeps the entry's
    /// allocations only if the caller chooses to overwrite in place —
    /// a fresh connection must never observe a stale key).
    pub fn reset(&mut self, loop_idx: usize) {
        self.loop_idx = loop_idx;
        self.key = None;
    }
}

/// Callbacks the service installs into the reactor to run the
/// shared-nothing data plane. The transport stays policy-free: it only
/// knows that a request may belong to a different loop (`route`) and
/// that each loop must offer the service a slice of its event-loop turn
/// (`on_tick`) to drain cross-loop work mailboxes.
pub trait LoopHooks: Send + Sync {
    /// Called once on each event-loop thread before it starts polling.
    /// `wake` wakes this loop's poller from any thread; the service
    /// registers it so mailbox posts can interrupt an idle `epoll_wait`.
    fn on_loop_start(&self, loop_idx: usize, wake: Arc<dyn Fn() + Send + Sync>);

    /// Called once per event-loop iteration, after timers fire. The
    /// poll timeout bounds how stale a tick can be (≤100 ms even when
    /// the loop is otherwise idle).
    fn on_tick(&self, loop_idx: usize);

    /// Which loop owns `req`'s session, if the request is keyed and
    /// parseable. `None` means "no opinion" — serve it where it landed.
    fn route(&self, req: &Request<'_>, ctx: &mut ConnCtx) -> Option<usize>;
}

/// Look up `name` in a raw `a=b&c=d` query string, returning the value
/// still percent-encoded. Lets callers distinguish "absent" from
/// "present but undecodable" (the latter must be a 400, not a silent
/// fall-back to defaults).
pub fn query_get_raw<'a>(query: &'a str, name: &str) -> Option<&'a str> {
    for pair in query.split('&') {
        if pair.is_empty() {
            continue;
        }
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        match percent_decode(k) {
            Some(key) if key == name => return Some(v),
            _ => {}
        }
    }
    None
}

/// Look up and decode `name` (shared with tests and the loadgen client).
/// `None` for both absent and undecodable values; use
/// [`query_get_raw`] + [`percent_decode`] to tell them apart.
pub fn query_get<'a>(query: &'a str, name: &str) -> Option<Cow<'a, str>> {
    percent_decode(query_get_raw(query, name)?)
}

/// Percent-decode (`%XX` and `+`). Borrowed when no escapes are present;
/// `None` when the decoded bytes are not valid UTF-8 (deterministic
/// rejection instead of silent U+FFFD substitution). A `%` not followed
/// by two hex digits passes through literally, matching common lenient
/// parsers.
pub fn percent_decode(s: &str) -> Option<Cow<'_, str>> {
    if !s.bytes().any(|b| b == b'%' || b == b'+') {
        return Some(Cow::Borrowed(s));
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                let hex = bytes.get(i + 1..i + 3);
                match hex.and_then(|h| u8::from_str_radix(std::str::from_utf8(h).ok()?, 16).ok())
                {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out).ok().map(Cow::Owned)
}

/// The response a handler fills in. The body buffer is cleared — not
/// freed — between requests, so steady-state serialization into it is
/// allocation-free.
pub struct ResponseBuf {
    status: u16,
    content_type: &'static str,
    /// Serialized response body; handlers append (via [`JsonWriter`] or
    /// `extend_from_slice`) after [`ResponseBuf::reset`].
    pub body: Vec<u8>,
    /// Reusable text scratch for handlers (e.g. config descriptions
    /// streamed into the body) — same lifecycle as `body`, and its
    /// growth is counted as an alloc event too.
    pub scratch: String,
}

impl ResponseBuf {
    pub fn new() -> ResponseBuf {
        ResponseBuf {
            status: 200,
            content_type: "application/json",
            body: Vec::with_capacity(512),
            scratch: String::with_capacity(128),
        }
    }

    /// Clear for the next request (keeps capacity).
    pub fn reset(&mut self) {
        self.status = 200;
        self.content_type = "application/json";
        self.body.clear();
        self.scratch.clear();
    }

    pub fn status(&self) -> u16 {
        self.status
    }

    pub fn set_status(&mut self, status: u16) {
        self.status = status;
    }

    /// Replace the response with a plain-text body.
    pub fn text(&mut self, status: u16, body: &str) {
        self.status = status;
        self.content_type = "text/plain; charset=utf-8";
        self.body.clear();
        self.body.extend_from_slice(body.as_bytes());
    }

    /// Replace the response with a `{"error": msg}` JSON envelope.
    pub fn error(&mut self, status: u16, msg: &str) {
        self.status = status;
        self.content_type = "application/json";
        self.body.clear();
        let mut w = JsonWriter::new(&mut self.body);
        w.begin_obj();
        w.field_str("error", msg);
        w.end_obj();
    }
}

impl Default for ResponseBuf {
    fn default() -> Self {
        Self::new()
    }
}

/// Run the handler against a parsed request with growth accounting:
/// response-body and scratch growth is detected here, in code shared by
/// both transports, so they count identically by construction.
pub(crate) fn dispatch(
    handler: &HttpHandler,
    req: &Request<'_>,
    ctx: &mut ConnCtx,
    resp: &mut ResponseBuf,
    stats: &TransportStats,
) {
    resp.reset();
    let body_cap = resp.body.capacity();
    let scratch_cap = resp.scratch.capacity();
    handler(req, ctx, resp);
    if resp.body.capacity() != body_cap || resp.scratch.capacity() != scratch_cap {
        stats.note_alloc();
    }
}

/// Assemble status line + headers + body into the reusable frame buffer
/// (so each response can go out as a single write). Frame growth is a
/// counted alloc event — shared accounting, like [`dispatch`].
pub(crate) fn assemble_frame(
    frame: &mut Vec<u8>,
    resp: &ResponseBuf,
    keep_alive: bool,
    stats: &TransportStats,
) {
    use std::io::Write as _;
    let cap_before = frame.capacity();
    frame.clear();
    let _ = write!(
        frame,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        parser::status_text(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    frame.extend_from_slice(&resp.body);
    if frame.capacity() != cap_before {
        stats.note_alloc();
    }
}

/// The request handler shared by all worker/event-loop threads: parse
/// the borrowed request, serialize into the reusable response buffer.
/// The [`ConnCtx`] is the connection's dispatch context (driving loop,
/// key cache) — owned by the transport, mutated by the handler.
pub type HttpHandler =
    Arc<dyn Fn(&Request<'_>, &mut ConnCtx, &mut ResponseBuf) + Send + Sync>;

/// Which transport backend serves the listener.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Event-driven reactor (epoll/poll readiness loops) — the default.
    Reactor,
    /// Legacy accept-thread + fixed worker pool (one thread per
    /// connection in flight). Kept as the differential baseline.
    Blocking,
}

impl TransportKind {
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Reactor => "reactor",
            TransportKind::Blocking => "blocking",
        }
    }

    /// Parse a `--transport` CLI value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "reactor" | "epoll" => Some(TransportKind::Reactor),
            "blocking" | "threads" => Some(TransportKind::Blocking),
            _ => None,
        }
    }
}

/// Full start options for [`HttpServer::start_with_opts`].
pub struct TransportOptions {
    pub kind: TransportKind,
    /// Event loops (reactor) or worker threads (blocking).
    pub threads: usize,
    /// Externally owned counters (the service exports them on `/metrics`).
    pub stats: Arc<TransportStats>,
    /// Serve-side chaos layer. When armed, its `accept` fault point
    /// closes a just-accepted connection before a byte is served — the
    /// client sees a reset, exactly like a flaky edge link. `None` keeps
    /// the accept path untouched (zero overhead without `--chaos`).
    pub chaos: Option<Arc<crate::chaos::ChaosLayer>>,
    /// Flight recorder for `conn_open`/`conn_close` events (reactor).
    pub recorder: Option<Arc<Recorder>>,
    /// Shared-nothing data-plane hooks (routing, per-loop ticks). `None`
    /// serves every request where it lands — the blocking pool and the
    /// single-loop reactor never consult hooks.
    pub hooks: Option<Arc<dyn LoopHooks>>,
}

impl TransportOptions {
    pub fn new(kind: TransportKind, threads: usize) -> TransportOptions {
        TransportOptions {
            kind,
            threads,
            stats: Arc::new(TransportStats::default()),
            chaos: None,
            recorder: None,
            hooks: None,
        }
    }
}

/// A running HTTP server over one of the two transport backends.
pub enum HttpServer {
    Blocking(blocking::BlockingServer),
    #[cfg(unix)]
    Reactor(reactor::ReactorServer),
}

impl HttpServer {
    /// Start serving `listener` on the default backend (the reactor on
    /// unix; the blocking pool elsewhere) with `threads` loops/workers.
    pub fn start(listener: TcpListener, threads: usize, handler: HttpHandler) -> Result<HttpServer> {
        Self::start_with_opts(listener, handler, TransportOptions::new(default_kind(), threads))
    }

    /// Full-option start (backend, shared stats, chaos, recorder).
    pub fn start_with_opts(
        listener: TcpListener,
        handler: HttpHandler,
        opts: TransportOptions,
    ) -> Result<HttpServer> {
        match opts.kind {
            TransportKind::Blocking => {
                Ok(HttpServer::Blocking(blocking::BlockingServer::start(listener, handler, opts)?))
            }
            #[cfg(unix)]
            TransportKind::Reactor => {
                Ok(HttpServer::Reactor(reactor::ReactorServer::start(listener, handler, opts)?))
            }
            // No readiness syscalls to build a reactor on: serve with the
            // portable blocking pool instead of failing to boot.
            #[cfg(not(unix))]
            TransportKind::Reactor => {
                Ok(HttpServer::Blocking(blocking::BlockingServer::start(listener, handler, opts)?))
            }
        }
    }

    /// The bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        match self {
            HttpServer::Blocking(s) => s.addr(),
            #[cfg(unix)]
            HttpServer::Reactor(s) => s.addr(),
        }
    }

    /// Transport counters (connections, requests, alloc events).
    pub fn stats(&self) -> Arc<TransportStats> {
        match self {
            HttpServer::Blocking(s) => s.stats(),
            #[cfg(unix)]
            HttpServer::Reactor(s) => s.stats(),
        }
    }

    /// Stop accepting, close connections, join all threads.
    pub fn stop(self) {
        match self {
            HttpServer::Blocking(s) => s.stop(),
            #[cfg(unix)]
            HttpServer::Reactor(s) => s.stop(),
        }
    }

    /// Block until the server exits on its own (never, in practice) —
    /// used by the `lasp serve` CLI to park the main thread.
    pub fn join(self) {
        match self {
            HttpServer::Blocking(s) => s.join(),
            #[cfg(unix)]
            HttpServer::Reactor(s) => s.join(),
        }
    }
}

/// The default backend for this platform.
pub fn default_kind() -> TransportKind {
    if cfg!(unix) {
        TransportKind::Reactor
    } else {
        TransportKind::Blocking
    }
}

/// Default event-loop count: one per core.
pub fn default_event_loops() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c").unwrap(), "a b c");
        let plain = percent_decode("plain").unwrap();
        assert_eq!(plain, "plain");
        assert!(matches!(plain, Cow::Borrowed(_)), "plain values must borrow");
        assert_eq!(percent_decode("bad%zz").unwrap(), "bad%zz");
        assert_eq!(percent_decode("%41").unwrap(), "A");
        // Invalid UTF-8 after decoding is rejected deterministically,
        // never lossy-substituted.
        assert_eq!(percent_decode("%FF"), None);
        assert_eq!(percent_decode("ok%FFtail"), None);
    }

    #[test]
    fn query_lookup() {
        assert_eq!(query_get("a=1&b=two", "b").unwrap(), "two");
        assert_eq!(query_get("a=1&b=two", "a").unwrap(), "1");
        assert_eq!(query_get("flag", "flag").unwrap(), "");
        assert_eq!(query_get("a=1", "missing"), None);
        assert_eq!(query_get("k=%FF", "k"), None);
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("reactor"), Some(TransportKind::Reactor));
        assert_eq!(TransportKind::parse("epoll"), Some(TransportKind::Reactor));
        assert_eq!(TransportKind::parse("blocking"), Some(TransportKind::Blocking));
        assert_eq!(TransportKind::parse("tokio"), None);
        assert_eq!(TransportKind::Reactor.name(), "reactor");
    }
}
