//! The slice-based HTTP/1.1 request parser and the reusable
//! per-connection read buffer, shared verbatim by both transports
//! ([`super::blocking`] and [`super::reactor`]).
//!
//! Parsing yields *byte ranges* into the connection buffer, never owned
//! strings, so the steady state performs zero heap allocations. Every
//! buffer-growth event is counted through [`TransportStats`] **inside
//! this module** — the transports cannot diverge in what they count,
//! which is what makes the differential alloc-parity assertion
//! meaningful.

use super::TransportStats;
use std::io::Read;
use std::time::{Duration, Instant};

/// Request bodies above this are rejected with 413 (a suggest/report
/// payload is a few hundred bytes).
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Header-section ceiling: request line + all headers must fit (431).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Header-count ceiling (431) — a malicious client cannot make the server
/// spend unbounded parse work per request.
pub const MAX_HEADERS: usize = 64;
/// Initial per-connection read-buffer size; grows (counted) on demand up
/// to the header + body ceilings.
pub const INITIAL_BUF: usize = 4 * 1024;
/// A request must arrive in full within this window of its first byte.
/// Bounds slow-loris hold time: a client trickling a request (or stalling
/// mid-request) is evicted with 408 instead of pinning a pool worker (or
/// a reactor slab slot) forever. Generous enough for any legitimate
/// client on a bad link.
pub const REQUEST_DEADLINE: Duration = Duration::from_secs(10);
/// After responding to a malformed request the connection lingers this
/// long, draining unread bytes, so closing cannot RST the error response
/// away before the client reads it.
pub const LINGER: Duration = Duration::from_millis(250);

pub(crate) fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Reusable per-connection read buffer. Bytes live in `data[start..filled]`;
/// parsing slices into that window, and `consume` reclaims the prefix.
pub(crate) struct ConnBuf {
    pub(crate) data: Vec<u8>,
    pub(crate) start: usize,
    pub(crate) filled: usize,
    /// When the first byte of the currently pending request arrived
    /// (None = no partial request buffered). Drives [`REQUEST_DEADLINE`].
    pub(crate) since: Option<Instant>,
}

impl ConnBuf {
    pub(crate) fn new() -> ConnBuf {
        ConnBuf { data: vec![0u8; INITIAL_BUF], start: 0, filled: 0, since: None }
    }

    /// Forget any buffered bytes (new connection); keeps capacity.
    pub(crate) fn reset(&mut self) {
        self.start = 0;
        self.filled = 0;
        self.since = None;
    }

    pub(crate) fn window(&self) -> &[u8] {
        &self.data[self.start..self.filled]
    }

    pub(crate) fn len(&self) -> usize {
        self.filled - self.start
    }

    /// When the currently pending (partial) request started arriving.
    pub(crate) fn pending_since(&self) -> Option<Instant> {
        self.since
    }

    /// The pending (partial) request has overstayed [`REQUEST_DEADLINE`].
    pub(crate) fn deadline_exceeded(&self) -> bool {
        matches!(self.since, Some(t) if t.elapsed() > REQUEST_DEADLINE)
    }

    /// Drop `n` parsed bytes from the front of the window.
    pub(crate) fn consume(&mut self, n: usize) {
        self.start = (self.start + n).min(self.filled);
        if self.start == self.filled {
            self.start = 0;
            self.filled = 0;
            self.since = None;
        } else {
            // Pipelined follow-up already buffered: its clock starts now.
            self.since = Some(Instant::now());
        }
    }

    /// Read more bytes from `stream`, compacting or growing first if the
    /// tail is full. Growth is a counted alloc event (shared accounting —
    /// both transports go through this exact path); steady state hits the
    /// high-water capacity and never grows again.
    pub(crate) fn fill(
        &mut self,
        stream: &mut impl Read,
        stats: &TransportStats,
    ) -> std::io::Result<usize> {
        if self.filled == self.data.len() {
            if self.start > 0 {
                self.data.copy_within(self.start..self.filled, 0);
                self.filled -= self.start;
                self.start = 0;
            } else {
                let new_len = (self.data.len() * 2).min(MAX_HEADER_BYTES + MAX_BODY_BYTES + 1024);
                if new_len > self.data.len() {
                    self.data.resize(new_len, 0);
                    stats.note_alloc();
                } else {
                    // Window already at the absolute ceiling; the parser
                    // rejects such requests before asking for more.
                    return Ok(0);
                }
            }
        }
        let was_empty = self.len() == 0;
        let n = stream.read(&mut self.data[self.filled..])?;
        self.filled += n;
        if was_empty && n > 0 {
            self.since = Some(Instant::now());
        }
        Ok(n)
    }
}

/// Byte ranges of one parsed request, relative to the buffer window at
/// parse time (no borrows, so the caller can keep mutating the buffer
/// before re-slicing).
pub(crate) struct Parsed {
    pub(crate) method: std::ops::Range<usize>,
    pub(crate) path: std::ops::Range<usize>,
    pub(crate) query: std::ops::Range<usize>,
    pub(crate) body: std::ops::Range<usize>,
    pub(crate) total_len: usize,
    pub(crate) close: bool,
}

pub(crate) enum TryParse {
    /// A complete request is buffered.
    Complete(Parsed),
    /// Not enough bytes yet.
    NeedMore,
    /// Protocol violation; respond with `status` and drop the connection.
    Bad(u16, &'static str),
}

/// Find the blank line ending the header section: a line break followed
/// immediately by another line break, where each break is `\n` or `\r\n`
/// (the old line-based parser tolerated LF-only and mixed endings; keep
/// accepting them). One short-circuiting pass — never scans past the
/// header region into buffered body bytes. Returns `(head_len,
/// body_start)`.
pub(crate) fn find_head_end(data: &[u8]) -> Option<(usize, usize)> {
    let mut i = 0;
    while i < data.len() {
        if data[i] == b'\n' {
            match data.get(i + 1) {
                Some(b'\n') => return Some((i, i + 2)),
                Some(b'\r') if data.get(i + 2) == Some(&b'\n') => return Some((i, i + 3)),
                _ => {}
            }
        }
        i += 1;
    }
    None
}

/// Attempt to parse one request from `data` (the buffer window).
pub(crate) fn try_parse(data: &[u8]) -> TryParse {
    // Locate the end of the header section.
    let Some((hdr_end, body_start)) = find_head_end(data) else {
        return if data.len() > MAX_HEADER_BYTES {
            TryParse::Bad(431, "headers too large")
        } else {
            TryParse::NeedMore
        };
    };
    if hdr_end > MAX_HEADER_BYTES {
        return TryParse::Bad(431, "headers too large");
    }
    let Ok(head) = std::str::from_utf8(&data[..hdr_end]) else {
        return TryParse::Bad(400, "non-ASCII request head");
    };
    let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(target), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return TryParse::Bad(400, "bad request line");
    };
    if !version.starts_with("HTTP/1.") {
        return TryParse::Bad(400, "unsupported HTTP version");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };

    // Headers.
    let mut content_length: Option<usize> = None;
    let mut close = version == "HTTP/1.0";
    let mut n_headers = 0usize;
    for line in lines {
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return TryParse::Bad(431, "too many headers");
        }
        let Some((name, value)) = line.split_once(':') else {
            return TryParse::Bad(400, "bad header");
        };
        let name = name.trim();
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            match value.parse::<usize>() {
                Ok(n) if n <= MAX_BODY_BYTES => {
                    // Conflicting duplicates are a framing-desync
                    // (request smuggling) vector: reject per RFC 7230.
                    if matches!(content_length, Some(prev) if prev != n) {
                        return TryParse::Bad(400, "conflicting content-length");
                    }
                    content_length = Some(n);
                }
                Ok(_) => return TryParse::Bad(413, "body too large"),
                Err(_) => return TryParse::Bad(400, "bad content-length"),
            }
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            // Chunked framing is not implemented; silently ignoring it
            // would desync the pipelined stream at the chunk headers.
            return TryParse::Bad(501, "transfer-encoding not supported");
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                close = true;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                close = false;
            }
        }
    }
    let content_length = content_length.unwrap_or(0);

    let total_len = body_start + content_length;
    if data.len() < total_len {
        return TryParse::NeedMore;
    }

    let range_in = |s: &str| -> std::ops::Range<usize> {
        let off = s.as_ptr() as usize - data.as_ptr() as usize;
        off..off + s.len()
    };
    // An absent query is the static "" (not inside `data`): empty range.
    let query = if query.is_empty() { 0..0 } else { range_in(query) };
    TryParse::Complete(Parsed {
        method: range_in(method),
        path: range_in(path),
        query,
        body: body_start..total_len,
        total_len,
        close,
    })
}

pub(crate) fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_end_handles_all_line_ending_mixes() {
        // CRLF throughout.
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\nHost: x\r\n\r\nBODY"), Some((24, 27)));
        // LF throughout.
        assert_eq!(find_head_end(b"A\nB\n\nrest"), Some((3, 5)));
        // LF lines closed by a CRLF blank line (old parser accepted it).
        assert_eq!(find_head_end(b"A\nB\n\r\nrest"), Some((3, 6)));
        // Incomplete head.
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\nHost"), None);
    }

    #[test]
    fn partial_request_deadline_trips() {
        // The stall guard itself (no 10 s wait): a pending request whose
        // first byte is older than the deadline must be evicted.
        // checked_sub: Instant is monotonic-since-boot on Linux, and
        // subtracting past the clock origin panics (fresh containers).
        let Some(stale) = Instant::now().checked_sub(REQUEST_DEADLINE + Duration::from_millis(10))
        else {
            return; // uptime < deadline: cannot fabricate a stale instant
        };
        let mut conn = ConnBuf::new();
        conn.filled = 4; // pretend 4 bytes arrived
        conn.since = Some(stale);
        assert!(conn.deadline_exceeded());
        conn.reset();
        assert!(!conn.deadline_exceeded());
    }
}
