//! Readiness polling behind a small [`Poller`] trait: `epoll` on Linux,
//! portable `poll(2)` everywhere else on unix (and on Linux under
//! `LASP_POLLER=poll`, so the fallback stays tested).
//!
//! No `libc`/`mio` crates exist in this offline build, so the handful of
//! syscalls the reactor needs are declared directly as `extern "C"` —
//! std already links the platform libc, so the symbols resolve at link
//! time. Everything raw lives in [`sys`]; the rest of the crate only
//! sees safe wrappers.

use std::io;
use std::os::fd::RawFd;
use std::time::Duration;

/// Raw libc declarations (see module docs). Kept minimal: readiness
/// syscalls, a self-pipe for cross-thread wakeups, and the fd-rlimit
/// helpers the high-connection bench/tests use.
pub mod sys {
    use std::os::raw::{c_int, c_short};

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;
    pub const POLLNVAL: c_short = 0x020;

    pub const F_GETFL: c_int = 3;
    pub const F_SETFL: c_int = 4;
    #[cfg(target_os = "linux")]
    pub const O_NONBLOCK: c_int = 0o4000;
    #[cfg(not(target_os = "linux"))]
    pub const O_NONBLOCK: c_int = 0x0004;

    /// `epoll_event` carries `__EPOLL_PACKED` on x86 glibc; mirroring the
    /// layout exactly is what keeps `epoll_wait` writes in bounds.
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    pub struct epoll_event {
        pub events: u32,
        pub u64: u64,
    }

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct pollfd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    #[cfg(target_os = "linux")]
    pub type nfds_t = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type nfds_t = std::os::raw::c_uint;

    #[cfg(target_os = "linux")]
    pub const RLIMIT_NOFILE: c_int = 7;
    #[cfg(not(target_os = "linux"))]
    pub const RLIMIT_NOFILE: c_int = 8;

    #[repr(C)]
    pub struct rlimit {
        pub rlim_cur: u64,
        pub rlim_max: u64,
    }

    extern "C" {
        #[cfg(target_os = "linux")]
        pub fn epoll_create1(flags: c_int) -> c_int;
        #[cfg(target_os = "linux")]
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut epoll_event) -> c_int;
        #[cfg(target_os = "linux")]
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut epoll_event,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn poll(fds: *mut pollfd, nfds: nfds_t, timeout: c_int) -> c_int;
        pub fn pipe(fds: *mut c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
        pub fn getrlimit(resource: c_int, rlim: *mut rlimit) -> c_int;
        pub fn setrlimit(resource: c_int, rlim: *const rlimit) -> c_int;
    }
}

/// Raise the soft open-file limit to `min(want, hard limit)`. Returns
/// the resulting soft limit. The 10k-connection bench series and the
/// idle-connection tests call this so they do not depend on the shell's
/// `ulimit -n` (CI additionally raises it for the bench step).
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    unsafe {
        let mut lim = sys::rlimit { rlim_cur: 0, rlim_max: 0 };
        if sys::getrlimit(sys::RLIMIT_NOFILE, &mut lim) != 0 {
            return Err(io::Error::last_os_error());
        }
        let target = want.min(lim.rlim_max);
        if target > lim.rlim_cur {
            let new = sys::rlimit { rlim_cur: target, rlim_max: lim.rlim_max };
            if sys::setrlimit(sys::RLIMIT_NOFILE, &new) != 0 {
                return Err(io::Error::last_os_error());
            }
            return Ok(target);
        }
        Ok(lim.rlim_cur)
    }
}

/// What a registration wants to be woken for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interest {
    Read,
    Write,
}

/// One readiness event delivered by [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
    /// Error/hangup on the fd; the connection should be torn down after
    /// a final read attempt drains whatever the peer managed to send.
    pub hangup: bool,
}

/// A minimal readiness selector. Level-triggered semantics on both
/// backends: an event keeps firing while the condition holds, so a loop
/// that processes partially and returns is never starved.
pub trait Poller: Send {
    fn add(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()>;
    fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()>;
    fn remove(&mut self, fd: RawFd) -> io::Result<()>;
    /// Wait up to `timeout` and append readiness events to `out`
    /// (cleared first). Returns the number of events delivered.
    fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<usize>;
    fn name(&self) -> &'static str;
}

/// Build the platform-preferred poller: epoll on Linux (unless
/// `LASP_POLLER=poll` forces the fallback), `poll(2)` elsewhere.
pub fn new_poller() -> io::Result<Box<dyn Poller>> {
    #[cfg(target_os = "linux")]
    {
        if std::env::var("LASP_POLLER").map(|v| v == "poll").unwrap_or(false) {
            return Ok(Box::new(PollPoller::new()));
        }
        return Ok(Box::new(EpollPoller::new()?));
    }
    #[allow(unreachable_code)]
    Ok(Box::new(PollPoller::new()))
}

/// Set `O_NONBLOCK` on a raw fd (pipes; sockets use std's setter).
fn set_nonblocking(fd: RawFd) -> io::Result<()> {
    unsafe {
        let flags = sys::fcntl(fd, sys::F_GETFL, 0);
        if flags < 0 {
            return Err(io::Error::last_os_error());
        }
        if sys::fcntl(fd, sys::F_SETFL, flags | sys::O_NONBLOCK) < 0 {
            return Err(io::Error::last_os_error());
        }
    }
    Ok(())
}

fn timeout_ms(timeout: Duration) -> i32 {
    // Round up so a 1ns timeout does not become a busy-loop zero.
    let ms = timeout.as_millis().min(i32::MAX as u128 - 1) as i32;
    ms + i32::from(timeout.subsec_nanos() % 1_000_000 != 0)
}

/// Linux epoll backend.
#[cfg(target_os = "linux")]
pub struct EpollPoller {
    epfd: RawFd,
    /// Reused event buffer for `epoll_wait` (no per-wakeup allocation).
    events: Vec<sys::epoll_event>,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    pub fn new() -> io::Result<EpollPoller> {
        let epfd = unsafe { sys::epoll_create1(0) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EpollPoller { epfd, events: vec![sys::epoll_event { events: 0, u64: 0 }; 256] })
    }

    fn ctl(&self, op: i32, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        let events = match interest {
            Interest::Read => sys::EPOLLIN,
            Interest::Write => sys::EPOLLOUT,
        };
        let mut ev = sys::epoll_event { events, u64: token as u64 };
        if unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollPoller {
    fn drop(&mut self) {
        unsafe { sys::close(self.epfd) };
    }
}

#[cfg(target_os = "linux")]
impl Poller for EpollPoller {
    fn add(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, interest)
    }

    fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, interest)
    }

    fn remove(&mut self, fd: RawFd) -> io::Result<()> {
        let mut ev = sys::epoll_event { events: 0, u64: 0 };
        if unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, &mut ev) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<usize> {
        out.clear();
        let n = unsafe {
            sys::epoll_wait(
                self.epfd,
                self.events.as_mut_ptr(),
                self.events.len() as i32,
                timeout_ms(timeout),
            )
        };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(err);
        }
        for ev in &self.events[..n as usize] {
            // Copy out of the (possibly packed) struct before use.
            let bits = ev.events;
            let token = ev.u64 as usize;
            out.push(Event {
                token,
                readable: bits & sys::EPOLLIN != 0,
                writable: bits & sys::EPOLLOUT != 0,
                hangup: bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0,
            });
        }
        Ok(out.len())
    }

    fn name(&self) -> &'static str {
        "epoll"
    }
}

/// Portable `poll(2)` backend — keeps macOS (and `LASP_POLLER=poll`
/// test runs) working. O(n) per wait, which is fine for its role as the
/// correctness fallback; the 10k-connection path runs on epoll.
pub struct PollPoller {
    /// Registered fds in insertion order; `pollfds` mirrors this layout
    /// and both vecs are reused across waits (no steady-state growth).
    tokens: Vec<(RawFd, usize)>,
    pollfds: Vec<sys::pollfd>,
}

impl PollPoller {
    pub fn new() -> PollPoller {
        PollPoller { tokens: Vec::with_capacity(64), pollfds: Vec::with_capacity(64) }
    }
}

impl Default for PollPoller {
    fn default() -> Self {
        Self::new()
    }
}

impl Poller for PollPoller {
    fn add(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        let events = match interest {
            Interest::Read => sys::POLLIN,
            Interest::Write => sys::POLLOUT,
        };
        self.tokens.push((fd, token));
        self.pollfds.push(sys::pollfd { fd, events, revents: 0 });
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        let Some(i) = self.tokens.iter().position(|&(f, _)| f == fd) else {
            return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
        };
        self.tokens[i].1 = token;
        self.pollfds[i].events = match interest {
            Interest::Read => sys::POLLIN,
            Interest::Write => sys::POLLOUT,
        };
        Ok(())
    }

    fn remove(&mut self, fd: RawFd) -> io::Result<()> {
        let Some(i) = self.tokens.iter().position(|&(f, _)| f == fd) else {
            return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
        };
        self.tokens.swap_remove(i);
        self.pollfds.swap_remove(i);
        Ok(())
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<usize> {
        out.clear();
        let n = unsafe {
            sys::poll(
                self.pollfds.as_mut_ptr(),
                self.pollfds.len() as sys::nfds_t,
                timeout_ms(timeout),
            )
        };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(err);
        }
        for (pfd, &(_, token)) in self.pollfds.iter().zip(&self.tokens) {
            let r = pfd.revents;
            if r == 0 {
                continue;
            }
            out.push(Event {
                token,
                readable: r & sys::POLLIN != 0,
                writable: r & sys::POLLOUT != 0,
                hangup: r & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0,
            });
        }
        Ok(out.len())
    }

    fn name(&self) -> &'static str {
        "poll"
    }
}

/// A self-pipe wakeup: the read end is registered in the loop's poller,
/// the write end ([`Waker`]) is shared with the accept thread and the
/// shutdown path. Writing one byte makes the sleeping loop's `wait`
/// return immediately.
pub struct WakePipe {
    rfd: RawFd,
    waker: std::sync::Arc<Waker>,
}

pub struct Waker {
    wfd: RawFd,
}

impl Waker {
    /// Nudge the owning event loop (best-effort: a full pipe already
    /// guarantees a pending wakeup).
    pub fn wake(&self) {
        let byte = 1u8;
        unsafe { sys::write(self.wfd, &byte, 1) };
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        unsafe { sys::close(self.wfd) };
    }
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        let mut fds = [0i32; 2];
        if unsafe { sys::pipe(fds.as_mut_ptr()) } != 0 {
            return Err(io::Error::last_os_error());
        }
        set_nonblocking(fds[0])?;
        set_nonblocking(fds[1])?;
        Ok(WakePipe { rfd: fds[0], waker: std::sync::Arc::new(Waker { wfd: fds[1] }) })
    }

    pub fn read_fd(&self) -> RawFd {
        self.rfd
    }

    pub fn waker(&self) -> std::sync::Arc<Waker> {
        self.waker.clone()
    }

    /// Drain pending wakeup bytes (nonblocking).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { sys::read(self.rfd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 || (n as usize) < buf.len() {
                return;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        unsafe { sys::close(self.rfd) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    fn connected_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    fn exercise(poller: &mut dyn Poller) {
        let (mut a, b) = connected_pair();
        b.set_nonblocking(true).unwrap();
        poller.add(b.as_raw_fd(), 7, Interest::Read).unwrap();

        // Nothing readable yet: a short wait returns empty.
        let mut events = Vec::new();
        poller.wait(&mut events, Duration::from_millis(10)).unwrap();
        assert!(events.iter().all(|e| !e.readable), "{}: spurious readable", poller.name());

        a.write_all(b"ping").unwrap();
        poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "{}: readable event missing",
            poller.name()
        );
        let mut buf = [0u8; 16];
        let n = (&b).read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");

        // Write interest on an idle socket fires immediately.
        poller.modify(b.as_raw_fd(), 9, Interest::Write).unwrap();
        poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
        assert!(
            events.iter().any(|e| e.token == 9 && e.writable),
            "{}: writable event missing",
            poller.name()
        );

        poller.remove(b.as_raw_fd()).unwrap();
        poller.wait(&mut events, Duration::from_millis(10)).unwrap();
        assert!(events.is_empty(), "{}: events after removal", poller.name());
    }

    #[test]
    fn poll_backend_delivers_readiness() {
        exercise(&mut PollPoller::new());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_backend_delivers_readiness() {
        exercise(&mut EpollPoller::new().unwrap());
    }

    #[test]
    fn wake_pipe_wakes_a_sleeping_poller() {
        let pipe = WakePipe::new().unwrap();
        let mut poller = PollPoller::new();
        poller.add(pipe.read_fd(), 0, Interest::Read).unwrap();
        let waker = pipe.waker();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
        });
        let mut events = Vec::new();
        let t0 = std::time::Instant::now();
        poller.wait(&mut events, Duration::from_secs(5)).unwrap();
        assert!(events.iter().any(|e| e.token == 0 && e.readable));
        assert!(t0.elapsed() < Duration::from_secs(2), "wakeup did not interrupt the wait");
        pipe.drain();
        t.join().unwrap();
    }

    #[test]
    fn nofile_limit_raises_or_reports() {
        // Must not error on any sane system; raising to the current soft
        // limit is a no-op that still returns the active value.
        let cur = raise_nofile_limit(64).expect("getrlimit works");
        assert!(cur >= 64 || cur > 0);
    }
}
