//! The event-driven transport: N event-loop threads, each owning a
//! poller, a slab of per-connection state machines, and a timer wheel.
//!
//! One accept thread (blocking accept keeps the chaos accept-drop fault
//! point byte-for-byte where the legacy transport had it) distributes
//! accepted sockets round-robin across the loops via per-loop inboxes +
//! wake pipes. Each loop then drives its connections entirely by
//! readiness:
//!
//! ```text
//!            readable                 complete request
//!   Reading ──────────▶ fill + parse ─────────────────▶ handle inline
//!      ▲                     │                               │
//!      │    flushed,         │ WouldBlock (socket dry)       │ write
//!      │    pipeline empty   ▼                               ▼
//!      └──────────── Writing (parked on EPOLLOUT) ◀── short write
//!                             │
//!                             │ after a malformed request's error
//!                             ▼   response is flushed
//!                         Draining (linger, discard reads, timer)
//! ```
//!
//! "Handling" is synchronous and inline on the loop thread: suggest /
//! report handlers are microsecond-scale CPU work, so parking the loop
//! in the handler is cheaper than any cross-thread hand-off — and it
//! makes the batch arena and the response/frame buffers genuinely
//! per-event-loop (the loop serves one request at a time, so one
//! [`ResponseBuf`] and one frame buffer serve every connection on it).
//!
//! The 408 slow-loris deadline and the post-error linger are enforced by
//! a coarse per-loop timer wheel (`TimerWheel`): 64 slots × 250 ms
//! covers the 10 s request deadline with one `Vec` push per armed
//! connection and lazy cancellation — a fired entry re-checks the
//! connection's real deadline and re-arms if it moved, so consuming a
//! request never has to hunt down its wheel entry.

use super::parser::{self, ConnBuf, TryParse, LINGER, REQUEST_DEADLINE};
use super::poller::{self, Event, Interest, Poller, WakePipe, Waker};
use super::{
    assemble_frame, dispatch, ConnCtx, HttpHandler, LoopHooks, Request, ResponseBuf,
    TransportOptions, TransportStats,
};
use crate::obs::{EventKind, Recorder};
use anyhow::{Context as _, Result};
use std::collections::VecDeque;
use std::io::{self, Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Idle loops wake at least this often to notice shutdown and advance
/// the timer wheel.
const POLL_TIMEOUT: Duration = Duration::from_millis(100);

/// Timer-wheel geometry: 64 slots × 250 ms ≈ 16 s horizon, comfortably
/// past [`REQUEST_DEADLINE`] (10 s); anything longer cascades through
/// the lazy re-arm on fire.
const WHEEL_SLOTS: usize = 64;
const WHEEL_TICK: Duration = Duration::from_millis(250);

/// What a connection is waiting for.
#[derive(Clone, Copy)]
enum ConnState {
    /// Waiting for request bytes (poller interest: readable).
    Reading,
    /// A response did not fit the socket buffer; parked on writable with
    /// the remainder staged in `Conn::pending`. Reads pause while
    /// parked — natural per-connection backpressure for pipelining.
    Writing { then: AfterWrite },
    /// Error response flushed for a malformed request; linger briefly
    /// discarding reads so closing cannot RST the response away.
    Draining,
}

/// What to do once a parked write finishes flushing.
#[derive(Clone, Copy, PartialEq)]
enum AfterWrite {
    /// Keep serving (process buffered pipelined requests, then read).
    Resume,
    /// Enter [`ConnState::Draining`] (the flushed frame was an error
    /// response to a malformed request).
    Linger,
    /// Close immediately (`Connection: close` or EOF mid-request).
    Close,
}

/// Outcome of driving one connection's state machine.
enum Drive {
    Keep,
    Close,
}

enum WriteOutcome {
    Flushed,
    Parked,
    Failed,
}

/// One connection owned by an event loop.
struct Conn {
    stream: TcpStream,
    buf: ConnBuf,
    state: ConnState,
    /// Unflushed response bytes (only populated while parked in
    /// `Writing`); `sent` is the flushed prefix.
    pending: Vec<u8>,
    sent: usize,
    /// Loop-unique id so stale timer entries cannot touch a different
    /// connection after slab-slot reuse.
    generation: u64,
    /// Requests served on this connection (reported in `conn_close`).
    requests: u64,
    /// A timer entry for this connection is in the wheel.
    timer_armed: bool,
    /// Current poller registration, to skip redundant `modify` calls.
    interest: Interest,
    /// Dispatch context (driving loop, session-key cache); travels with
    /// the connection when it is re-homed to its owning loop.
    ctx: ConnCtx,
}

/// Coarse hashed timer wheel; entries are `(token, generation)` and
/// cancellation is lazy (fired entries re-check the connection).
struct TimerWheel {
    slots: Vec<Vec<(usize, u64)>>,
    /// Slot index the next advance starts from.
    cursor: usize,
    /// Wall-clock anchor of `cursor`'s tick boundary.
    anchor: Instant,
}

impl TimerWheel {
    fn new(now: Instant) -> TimerWheel {
        TimerWheel { slots: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(), cursor: 0, anchor: now }
    }

    /// Arm `token` to fire at `deadline` (clamped to the wheel horizon;
    /// the lazy re-arm on fire covers anything longer).
    fn schedule(&mut self, now: Instant, deadline: Instant, token: usize, generation: u64) {
        let delay = deadline.saturating_duration_since(now);
        let ticks =
            ((delay.as_millis() / WHEEL_TICK.as_millis()) as usize + 1).min(WHEEL_SLOTS - 1);
        let slot = (self.cursor + ticks) % WHEEL_SLOTS;
        self.slots[slot].push((token, generation));
    }

    /// Move the wheel up to `now`, draining due entries into `fired`.
    fn advance(&mut self, now: Instant, fired: &mut Vec<(usize, u64)>) {
        while now.saturating_duration_since(self.anchor) >= WHEEL_TICK {
            self.anchor += WHEEL_TICK;
            self.cursor = (self.cursor + 1) % WHEEL_SLOTS;
            fired.append(&mut self.slots[self.cursor]);
        }
    }
}

/// Work handed to one event loop from outside: freshly accepted sockets
/// (from the accept thread, round-robin) and connections re-homed by a
/// sibling loop because this loop owns their session's shard
/// (shared-nothing routing). A handoff carries the socket, the read
/// buffer with the still-unconsumed request bytes, and the dispatch
/// context — the adopting loop serves the buffered request immediately,
/// without waiting for further socket readiness (the bytes are in
/// userspace; the poller would never report them again).
enum Incoming {
    New(TcpStream),
    Handoff { stream: TcpStream, buf: ConnBuf, ctx: ConnCtx, requests: u64 },
}

/// Inbox of [`Incoming`] work for one event loop.
type Inbox = Arc<Mutex<VecDeque<Incoming>>>;

/// A running reactor server: accept thread + N event-loop threads.
pub struct ReactorServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    stats: Arc<TransportStats>,
    wakers: Vec<Arc<Waker>>,
    accept_thread: JoinHandle<()>,
    loops: Vec<JoinHandle<()>>,
}

impl ReactorServer {
    /// Start serving `listener` with `opts.threads` event loops.
    pub fn start(
        listener: TcpListener,
        handler: HttpHandler,
        opts: TransportOptions,
    ) -> Result<ReactorServer> {
        let n_loops = opts.threads;
        assert!(n_loops > 0);
        let stats = opts.stats;
        let chaos = opts.chaos;
        let recorder = opts.recorder;
        let hooks = opts.hooks;
        let addr = listener.local_addr().context("reading bound address")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        stats.event_loops.store(n_loops as u64, Ordering::Relaxed);

        // Phase 1: create every loop's wake pipe and inbox up front —
        // re-homing a connection needs all-to-all reach (any loop must
        // be able to push into any sibling's inbox and wake it).
        let mut pipes = Vec::with_capacity(n_loops);
        let mut wakers = Vec::with_capacity(n_loops);
        let mut inbox_vec: Vec<Inbox> = Vec::with_capacity(n_loops);
        for _ in 0..n_loops {
            let pipe = WakePipe::new().context("creating event-loop wake pipe")?;
            wakers.push(pipe.waker());
            pipes.push(pipe);
            inbox_vec.push(Arc::new(Mutex::new(VecDeque::new())));
        }
        let inboxes = Arc::new(inbox_vec);
        let all_wakers: Arc<Vec<Arc<Waker>>> = Arc::new(wakers.clone());

        // Phase 2: spawn the loops, named for per-core profiling
        // (`lasp-loop-<i>` shows up in `top -H`, perf, and core dumps).
        let mut loops = Vec::with_capacity(n_loops);
        for (loop_idx, wake) in pipes.into_iter().enumerate() {
            let poller = poller::new_poller().context("creating poller")?;
            let mut el = EventLoop::new(
                loop_idx,
                poller,
                wake,
                inboxes.clone(),
                all_wakers.clone(),
                handler.clone(),
                shutdown.clone(),
                stats.clone(),
                recorder.clone(),
                hooks.clone(),
            )?;
            loops.push(
                std::thread::Builder::new()
                    .name(format!("lasp-loop-{loop_idx}"))
                    .spawn(move || el.run())
                    .context("spawning event loop")?,
            );
        }

        let accept_thread = {
            let shutdown = shutdown.clone();
            let stats = stats.clone();
            let wakers = wakers.clone();
            let inboxes = inboxes.clone();
            std::thread::spawn(move || {
                let mut next = 0usize;
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    let Ok(stream) = conn else { continue };
                    if let Some(c) = &chaos {
                        if c.accept_drop() {
                            // Close before a byte is served; the client
                            // sees a reset, as on a flaky edge link.
                            drop(stream);
                            continue;
                        }
                    }
                    let _ = stream.set_nodelay(true);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stats.connections.fetch_add(1, Ordering::Relaxed);
                    // Round-robin across loops; the wake byte interrupts
                    // the target loop's poller wait.
                    let i = next % wakers.len();
                    next = next.wrapping_add(1);
                    match inboxes[i].lock() {
                        Ok(mut q) => q.push_back(Incoming::New(stream)),
                        Err(_) => return,
                    }
                    wakers[i].wake();
                }
            })
        };

        Ok(ReactorServer { addr, shutdown, stats, wakers, accept_thread, loops })
    }

    /// The bound address (ephemeral ports resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Transport counters (connections, requests, alloc events, reactor
    /// gauges).
    pub fn stats(&self) -> Arc<TransportStats> {
        self.stats.clone()
    }

    /// Stop accepting, close every connection, join all threads.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept thread, then every sleeping event loop.
        let _ = TcpStream::connect(self.addr);
        for w in &self.wakers {
            w.wake();
        }
        let _ = self.accept_thread.join();
        for l in self.loops {
            let _ = l.join();
        }
    }

    /// Block until the server exits on its own (never, in practice) —
    /// used by the `lasp serve` CLI to park the main thread.
    pub fn join(self) {
        let _ = self.accept_thread.join();
        for l in self.loops {
            let _ = l.join();
        }
    }
}

/// What a fired timer entry turned out to mean, decided while the
/// connection is borrowed and acted on after the borrow ends.
enum TimerAction {
    Nothing,
    Close,
    Evict408,
    Rearm(Instant),
}

/// Per-thread reactor state. The response/frame buffers (and, via
/// `thread_local!`, the service's batch arena) are owned by the loop —
/// one of each per event loop, not per connection.
struct EventLoop {
    idx: usize,
    poller: Box<dyn Poller>,
    wake: WakePipe,
    /// Every loop's inbox (ours is `inboxes[idx]`); siblings' entries
    /// are the re-homing destinations.
    inboxes: Arc<Vec<Inbox>>,
    /// Every loop's waker, for waking a sibling after a handoff push.
    wakers: Arc<Vec<Arc<Waker>>>,
    handler: HttpHandler,
    shutdown: Arc<AtomicBool>,
    stats: Arc<TransportStats>,
    recorder: Option<Arc<Recorder>>,
    /// Shared-nothing data-plane hooks; `None` (or a single loop) means
    /// every request is served where it lands, with no routing parse.
    hooks: Option<Arc<dyn LoopHooks>>,
    /// Connection slab: `token = slot + 1` (token 0 is the wake pipe).
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// Loop-unique generation source (never reused, unlike slots).
    next_generation: u64,
    wheel: TimerWheel,
    resp: ResponseBuf,
    frame: Vec<u8>,
    /// Reused scratch for poller events and fired timers.
    events: Vec<Event>,
    fired: Vec<(usize, u64)>,
}

impl EventLoop {
    #[allow(clippy::too_many_arguments)]
    fn new(
        idx: usize,
        mut poller: Box<dyn Poller>,
        wake: WakePipe,
        inboxes: Arc<Vec<Inbox>>,
        wakers: Arc<Vec<Arc<Waker>>>,
        handler: HttpHandler,
        shutdown: Arc<AtomicBool>,
        stats: Arc<TransportStats>,
        recorder: Option<Arc<Recorder>>,
        hooks: Option<Arc<dyn LoopHooks>>,
    ) -> Result<EventLoop> {
        poller.add(wake.read_fd(), 0, Interest::Read).context("registering wake pipe")?;
        Ok(EventLoop {
            idx,
            poller,
            wake,
            inboxes,
            wakers,
            handler,
            shutdown,
            stats,
            recorder,
            hooks,
            conns: Vec::new(),
            free: Vec::new(),
            next_generation: 0,
            wheel: TimerWheel::new(Instant::now()),
            resp: ResponseBuf::new(),
            frame: Vec::with_capacity(1024),
            events: Vec::with_capacity(256),
            fired: Vec::new(),
        })
    }

    fn run(&mut self) {
        if let Some(h) = &self.hooks {
            let waker = self.wakers[self.idx].clone();
            h.on_loop_start(self.idx, Arc::new(move || waker.wake()));
        }
        loop {
            let mut events = std::mem::take(&mut self.events);
            let waited = self.poller.wait(&mut events, POLL_TIMEOUT);
            self.stats.wakeups.fetch_add(1, Ordering::Relaxed);
            if self.shutdown.load(Ordering::SeqCst) || waited.is_err() {
                // Shutdown, or the poller itself failed (unrecoverable
                // for this loop — drop its connections rather than spin).
                self.close_all();
                return;
            }
            for &ev in &events {
                if ev.token == 0 {
                    self.wake.drain();
                    continue;
                }
                let slot = ev.token - 1;
                if matches!(self.drive(slot, ev), Drive::Close) {
                    self.close(slot);
                }
            }
            events.clear();
            self.events = events;

            self.adopt_new_conns();
            self.fire_timers();
            // One tick per loop iteration: the service drains cross-loop
            // work mailboxes here. POLL_TIMEOUT bounds tick staleness.
            if let Some(h) = &self.hooks {
                h.on_tick(self.idx);
            }
        }
    }

    /// Pull incoming work out of this loop's inbox into the slab:
    /// freshly accepted sockets, and connections re-homed here because
    /// this loop owns their session's shard.
    fn adopt_new_conns(&mut self) {
        loop {
            let incoming = match self.inboxes[self.idx].lock() {
                Ok(mut q) => q.pop_front(),
                Err(_) => return,
            };
            let Some(incoming) = incoming else { return };
            let (stream, buf, ctx, requests, is_handoff) = match incoming {
                Incoming::New(stream) => {
                    (stream, ConnBuf::new(), ConnCtx::new(self.idx), 0, false)
                }
                Incoming::Handoff { stream, buf, mut ctx, requests } => {
                    ctx.loop_idx = self.idx;
                    (stream, buf, ctx, requests, true)
                }
            };
            let slot = match self.free.pop() {
                Some(s) => s,
                None => {
                    self.conns.push(None);
                    self.conns.len() - 1
                }
            };
            self.next_generation += 1;
            let fd = stream.as_raw_fd();
            if self.poller.add(fd, slot + 1, Interest::Read).is_err() {
                self.free.push(slot);
                continue;
            }
            let pending_since = buf.pending_since();
            self.conns[slot] = Some(Conn {
                stream,
                buf,
                state: ConnState::Reading,
                pending: Vec::new(),
                sent: 0,
                generation: self.next_generation,
                requests,
                timer_armed: false,
                interest: Interest::Read,
                ctx,
            });
            self.stats.conns_open.fetch_add(1, Ordering::Relaxed);
            if !is_handoff {
                // A handoff is a migration, not a new connection: the
                // origin loop's conn_open stands; no second event.
                if let Some(r) = &self.recorder {
                    r.record(EventKind::ConnOpen, self.idx as u64, (slot + 1) as u64, 0);
                }
                continue;
            }
            // The re-homed buffer may hold a partial follow-up request;
            // keep its 408 clock running on this loop's wheel.
            if let Some(since) = pending_since {
                let conn = self.conns[slot].as_mut().unwrap();
                conn.timer_armed = true;
                let generation = conn.generation;
                self.wheel.schedule(Instant::now(), since + REQUEST_DEADLINE, slot + 1, generation);
            }
            // Serve the buffered request now: the bytes already left the
            // kernel on the origin loop, so no readiness event will ever
            // fire for them here.
            if matches!(self.drive_reading(slot), Drive::Close) {
                self.close(slot);
            }
        }
    }

    /// Advance the wheel and act on connections whose deadline really
    /// passed (the lazy re-check re-arms deadlines that moved).
    fn fire_timers(&mut self) {
        let now = Instant::now();
        let mut fired = std::mem::take(&mut self.fired);
        self.wheel.advance(now, &mut fired);
        for &(token, generation) in &fired {
            let slot = token - 1;
            let action = {
                let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else {
                    continue;
                };
                if conn.generation != generation {
                    continue; // slot was reused; stale entry
                }
                match conn.state {
                    // Linger elapsed: the error response has had its
                    // window to be read. Close for real.
                    ConnState::Draining => TimerAction::Close,
                    ConnState::Reading => match conn.buf.pending_since() {
                        Some(since) => {
                            let due = since + REQUEST_DEADLINE;
                            if now >= due {
                                // Slow-loris eviction: the partial
                                // request overstayed its deadline.
                                TimerAction::Evict408
                            } else {
                                // Deadline moved (request completed and a
                                // newer one started): follow it.
                                TimerAction::Rearm(due)
                            }
                        }
                        None => {
                            conn.timer_armed = false;
                            TimerAction::Nothing
                        }
                    },
                    // Reads pause while parked on writable, so the
                    // request clock cannot be enforced here; keep
                    // patrolling until the write path unblocks (the
                    // read path re-checks the deadline itself).
                    ConnState::Writing { .. } => match conn.buf.pending_since() {
                        Some(_) => TimerAction::Rearm(now + WHEEL_TICK),
                        None => {
                            conn.timer_armed = false;
                            TimerAction::Nothing
                        }
                    },
                }
            };
            match action {
                TimerAction::Nothing => {}
                TimerAction::Close => self.close(slot),
                TimerAction::Rearm(due) => self.wheel.schedule(now, due, token, generation),
                TimerAction::Evict408 => {
                    if matches!(self.reject(slot, 408, "request timeout"), Drive::Close) {
                        self.close(slot);
                    }
                }
            }
        }
        fired.clear();
        self.fired = fired;
    }

    /// Route one readiness event through the connection's state machine.
    fn drive(&mut self, slot: usize, ev: Event) -> Drive {
        let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else {
            return Drive::Keep; // stale event for an already-closed slot
        };
        match conn.state {
            ConnState::Reading => {
                if ev.readable || ev.hangup {
                    self.drive_reading(slot)
                } else {
                    Drive::Keep
                }
            }
            ConnState::Writing { then } => {
                if !(ev.writable || ev.hangup) {
                    return Drive::Keep;
                }
                match flush_pending(conn) {
                    Ok(true) => {
                        conn.pending.clear();
                        conn.sent = 0;
                        match then {
                            AfterWrite::Close => Drive::Close,
                            AfterWrite::Linger => self.enter_draining(slot),
                            AfterWrite::Resume => {
                                conn.state = ConnState::Reading;
                                self.set_interest(slot, Interest::Read);
                                // Serve any pipelined requests that were
                                // buffered while parked.
                                self.drive_reading(slot)
                            }
                        }
                    }
                    Ok(false) => Drive::Keep, // still blocked
                    Err(_) => Drive::Close,
                }
            }
            ConnState::Draining => {
                // Discard whatever the client is still sending; EOF or
                // error ends the linger early.
                let mut scratch = [0u8; 1024];
                loop {
                    match (&conn.stream).read(&mut scratch) {
                        Ok(0) => return Drive::Close,
                        Ok(_) => {}
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Drive::Keep,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                        Err(_) => return Drive::Close,
                    }
                }
            }
        }
    }

    /// Fill + parse + serve until the socket runs dry, a response parks
    /// on writable, or the connection ends.
    fn drive_reading(&mut self, slot: usize) -> Drive {
        loop {
            // Serve every complete request already buffered.
            loop {
                let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else {
                    return Drive::Keep;
                };
                if conn.buf.len() == 0 {
                    break;
                }
                match parser::try_parse(conn.buf.window()) {
                    TryParse::Complete(p) => {
                        // Shared-nothing routing: if a sibling loop owns
                        // this request's session shard, re-home the whole
                        // connection there before counting or serving the
                        // request. Single-loop reactors skip the routing
                        // parse entirely — identical CPU/alloc profile to
                        // the pre-routing reactor.
                        let target = match &self.hooks {
                            Some(hooks) if self.inboxes.len() > 1 => {
                                let base = conn.buf.start;
                                let data = &conn.buf.data[base..conn.buf.filled];
                                let req = Request {
                                    method: std::str::from_utf8(&data[p.method.clone()])
                                        .unwrap_or(""),
                                    path: std::str::from_utf8(&data[p.path.clone()]).unwrap_or(""),
                                    query: std::str::from_utf8(&data[p.query.clone()])
                                        .unwrap_or(""),
                                    body: &data[p.body.clone()],
                                    close: p.close,
                                };
                                hooks.route(&req, &mut conn.ctx).filter(|&o| o != self.idx)
                            }
                            _ => None,
                        };
                        if let Some(owner) = target {
                            return self.rehome(slot, owner);
                        }
                        self.stats.requests.fetch_add(1, Ordering::Relaxed);
                        conn.requests += 1;
                        let close = {
                            let base = conn.buf.start;
                            let data = &conn.buf.data[base..conn.buf.filled];
                            // The head was validated as UTF-8 by try_parse.
                            let req = Request {
                                method: std::str::from_utf8(&data[p.method.clone()]).unwrap_or(""),
                                path: std::str::from_utf8(&data[p.path.clone()]).unwrap_or(""),
                                query: std::str::from_utf8(&data[p.query.clone()]).unwrap_or(""),
                                body: &data[p.body.clone()],
                                close: p.close,
                            };
                            dispatch(&self.handler, &req, &mut conn.ctx, &mut self.resp, &self.stats);
                            req.close
                        };
                        conn.buf.consume(p.total_len);
                        assemble_frame(&mut self.frame, &self.resp, !close, &self.stats);
                        let then = if close { AfterWrite::Close } else { AfterWrite::Resume };
                        match self.write_frame(slot, then) {
                            WriteOutcome::Flushed if close => return Drive::Close,
                            WriteOutcome::Flushed => continue,
                            WriteOutcome::Parked => return Drive::Keep,
                            WriteOutcome::Failed => return Drive::Close,
                        }
                    }
                    TryParse::Bad(status, msg) => {
                        if status == 431 {
                            self.stats.rejected_431.fetch_add(1, Ordering::Relaxed);
                        }
                        return self.reject(slot, status, msg);
                    }
                    TryParse::NeedMore => {
                        if conn.buf.deadline_exceeded() {
                            return self.reject(slot, 408, "request timeout");
                        }
                        break;
                    }
                }
            }

            // Need more bytes from the socket.
            let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else {
                return Drive::Keep;
            };
            match conn.buf.fill(&mut (&conn.stream), &self.stats) {
                Ok(0) => {
                    return if conn.buf.len() == 0 {
                        Drive::Close
                    } else {
                        // EOF mid-request: answer 400, then close (the
                        // peer already shut its write side; no linger).
                        self.reject_then_close(slot, 400, "eof mid-request")
                    };
                }
                Ok(_) => {
                    // The first byte of a pending request arms the 408
                    // deadline in the wheel (once; the fired entry
                    // follows the deadline as requests complete).
                    if !conn.timer_armed {
                        if let Some(since) = conn.buf.pending_since() {
                            conn.timer_armed = true;
                            let generation = conn.generation;
                            let now = Instant::now();
                            self.wheel.schedule(
                                now,
                                since + REQUEST_DEADLINE,
                                slot + 1,
                                generation,
                            );
                        }
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Drive::Keep,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return Drive::Close,
            }
        }
    }

    /// Re-home a connection to the loop that owns its session shard:
    /// deregister it here, hand the socket + read buffer (with the
    /// unconsumed request bytes) + dispatch context to the owner, and
    /// wake it. Counted once per migration in `forwarded` — after the
    /// first request, a keep-alive connection lives on its owner and
    /// never crosses loops again (until its key changes).
    fn rehome(&mut self, slot: usize, owner: usize) -> Drive {
        let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.take()) else {
            return Drive::Keep;
        };
        let _ = self.poller.remove(conn.stream.as_raw_fd());
        self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
        self.stats.forwarded.fetch_add(1, Ordering::Relaxed);
        self.free.push(slot);
        let handoff = Incoming::Handoff {
            stream: conn.stream,
            buf: conn.buf,
            ctx: conn.ctx,
            requests: conn.requests,
        };
        match self.inboxes[owner].lock() {
            // Poisoned sibling inbox: the process is already coming
            // down; dropping the connection is the only safe move.
            Ok(mut q) => q.push_back(handoff),
            Err(_) => return Drive::Keep,
        }
        self.wakers[owner].wake();
        Drive::Keep
    }

    /// Serve an error response for a protocol violation, then linger.
    fn reject(&mut self, slot: usize, status: u16, msg: &'static str) -> Drive {
        self.resp.reset();
        self.resp.error(status, msg);
        assemble_frame(&mut self.frame, &self.resp, false, &self.stats);
        match self.write_frame(slot, AfterWrite::Linger) {
            WriteOutcome::Flushed => self.enter_draining(slot),
            WriteOutcome::Parked => Drive::Keep,
            WriteOutcome::Failed => Drive::Close,
        }
    }

    /// Error response then immediate close (peer already sent EOF).
    fn reject_then_close(&mut self, slot: usize, status: u16, msg: &'static str) -> Drive {
        self.resp.reset();
        self.resp.error(status, msg);
        assemble_frame(&mut self.frame, &self.resp, false, &self.stats);
        match self.write_frame(slot, AfterWrite::Close) {
            WriteOutcome::Flushed => Drive::Close,
            WriteOutcome::Parked => Drive::Keep,
            WriteOutcome::Failed => Drive::Close,
        }
    }

    /// Write the assembled frame; on a short write park the connection
    /// on writable with the remainder staged.
    fn write_frame(&mut self, slot: usize, then: AfterWrite) -> WriteOutcome {
        let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else {
            return WriteOutcome::Failed;
        };
        let mut off = 0usize;
        while off < self.frame.len() {
            match (&conn.stream).write(&self.frame[off..]) {
                Ok(0) => return WriteOutcome::Failed,
                Ok(n) => off += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    // Stage the remainder and park on writable. The
                    // staging copy only happens under backpressure —
                    // never on the steady-state hot path.
                    conn.pending.clear();
                    conn.pending.extend_from_slice(&self.frame[off..]);
                    conn.sent = 0;
                    conn.state = ConnState::Writing { then };
                    self.stats.write_backpressure.fetch_add(1, Ordering::Relaxed);
                    self.set_interest(slot, Interest::Write);
                    return WriteOutcome::Parked;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return WriteOutcome::Failed,
            }
        }
        WriteOutcome::Flushed
    }

    /// Switch to the lingering-close state: interest back to readable
    /// (to observe EOF), reads discarded, wheel closes us after
    /// [`LINGER`].
    fn enter_draining(&mut self, slot: usize) -> Drive {
        let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else {
            return Drive::Keep;
        };
        conn.state = ConnState::Draining;
        let generation = conn.generation;
        let now = Instant::now();
        self.wheel.schedule(now, now + LINGER, slot + 1, generation);
        self.set_interest(slot, Interest::Read);
        Drive::Keep
    }

    /// Update the poller registration if the desired interest changed.
    fn set_interest(&mut self, slot: usize, interest: Interest) {
        let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else { return };
        if conn.interest != interest {
            conn.interest = interest;
            let fd = conn.stream.as_raw_fd();
            let _ = self.poller.modify(fd, slot + 1, interest);
        }
    }

    /// Deregister, close, and release one connection slot.
    fn close(&mut self, slot: usize) {
        let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.take()) else { return };
        let _ = self.poller.remove(conn.stream.as_raw_fd());
        self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
        if let Some(r) = &self.recorder {
            r.record(EventKind::ConnClose, self.idx as u64, (slot + 1) as u64, conn.requests);
        }
        drop(conn);
        self.free.push(slot);
    }

    fn close_all(&mut self) {
        for slot in 0..self.conns.len() {
            self.close(slot);
        }
    }
}

/// Flush a parked connection's staged bytes. `Ok(true)` = fully flushed.
fn flush_pending(conn: &mut Conn) -> io::Result<bool> {
    while conn.sent < conn.pending.len() {
        match (&conn.stream).write(&conn.pending[conn.sent..]) {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::WriteZero, "peer stopped reading")),
            Ok(n) => conn.sent += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_wheel_fires_at_and_after_deadline() {
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(t0);
        wheel.schedule(t0, t0 + Duration::from_millis(600), 5, 1);
        let mut fired = Vec::new();
        // Not yet due: advancing one tick must not fire it.
        wheel.advance(t0 + WHEEL_TICK, &mut fired);
        assert!(fired.is_empty());
        // Well past due: it must come out exactly once.
        wheel.advance(t0 + Duration::from_secs(2), &mut fired);
        assert_eq!(fired, vec![(5, 1)]);
        fired.clear();
        wheel.advance(t0 + Duration::from_secs(4), &mut fired);
        assert!(fired.is_empty(), "entries fire once");
    }

    #[test]
    fn timer_wheel_clamps_past_horizon_deadlines() {
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(t0);
        // 60 s is past the ~16 s horizon: the entry still fires (early),
        // relying on the caller's lazy re-arm to carry it the rest of
        // the way.
        wheel.schedule(t0, t0 + Duration::from_secs(60), 9, 3);
        let mut fired = Vec::new();
        wheel.advance(t0 + Duration::from_secs(17), &mut fired);
        assert_eq!(fired, vec![(9, 3)]);
    }
}
