//! Socket-level conformance tests run against **both** transport
//! backends: every case takes a [`TransportKind`] and the suite invokes
//! it once per backend, so the reactor cannot drift from the blocking
//! pool on protocol behavior (parsing tolerances, error statuses,
//! keep-alive, pipelining, the zero-alloc contract).

use super::*;
use crate::util::json::JsonWriter;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::time::Duration;

const BOTH: [TransportKind; 2] = [TransportKind::Reactor, TransportKind::Blocking];

fn echo_server(kind: TransportKind) -> HttpServer {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handler: HttpHandler = Arc::new(|req: &Request<'_>, _ctx: &mut ConnCtx, out: &mut ResponseBuf| {
        let mut w = JsonWriter::new(&mut out.body);
        w.begin_obj();
        w.field_str("method", req.method);
        w.field_str("path", req.path);
        w.field_num("body_len", req.body.len() as f64);
        if let Some(v) = req.query_get("q") {
            w.field_str("q", &v);
        }
        w.end_obj();
    });
    HttpServer::start_with_opts(listener, handler, TransportOptions::new(kind, 2)).unwrap()
}

fn raw_roundtrip(addr: SocketAddr, request: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// Read one full response (head + declared body) off a keep-alive
/// connection.
pub(crate) fn read_one_response(s: &mut TcpStream) -> String {
    let mut raw = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        if let Some(hdr_end) = find_subsequence(&raw, b"\r\n\r\n") {
            let head = String::from_utf8_lossy(&raw[..hdr_end]);
            let clen: usize = head
                .lines()
                .find_map(|l| {
                    let (name, value) = l.split_once(':')?;
                    name.trim()
                        .eq_ignore_ascii_case("content-length")
                        .then(|| value.trim().parse().ok())?
                })
                .unwrap_or(0);
            if raw.len() >= hdr_end + 4 + clen {
                return String::from_utf8_lossy(&raw[..hdr_end + 4 + clen]).into_owned();
            }
        }
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "connection closed early: {}", String::from_utf8_lossy(&raw));
        raw.extend_from_slice(&buf[..n]);
    }
}

#[test]
fn serves_get_with_query() {
    for kind in BOTH {
        let server = echo_server(kind);
        let resp = raw_roundtrip(
            server.addr(),
            b"GET /hello?q=a%20b HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "[{}] {resp}", kind.name());
        assert!(resp.contains("\"path\":\"/hello\""), "[{}] {resp}", kind.name());
        assert!(resp.contains("\"q\":\"a b\""), "[{}] {resp}", kind.name());
        server.stop();
    }
}

#[test]
fn serves_post_body_and_keep_alive() {
    for kind in BOTH {
        let server = echo_server(kind);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        for _ in 0..3 {
            let body = "{\"x\":1}";
            let req = format!(
                "POST /v1/echo HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            s.write_all(req.as_bytes()).unwrap();
            let text = read_one_response(&mut s);
            assert!(text.starts_with("HTTP/1.1 200 OK"), "[{}] {text}", kind.name());
            assert!(text.contains("\"body_len\":7"), "[{}] {text}", kind.name());
        }
        server.stop();
    }
}

#[test]
fn pipelined_requests_are_all_answered() {
    for kind in BOTH {
        let server = echo_server(kind);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        // Three requests in a single segment; responses must come back
        // in order on the same connection.
        let mut burst = Vec::new();
        for i in 0..3 {
            burst.extend_from_slice(format!("GET /pipe{i} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes());
        }
        s.write_all(&burst).unwrap();
        for i in 0..3 {
            let text = read_one_response(&mut s);
            assert!(text.starts_with("HTTP/1.1 200 OK"), "[{}] {text}", kind.name());
            assert!(text.contains(&format!("\"path\":\"/pipe{i}\"")), "[{}] {text}", kind.name());
        }
        server.stop();
    }
}

#[test]
fn split_reads_across_tcp_segments() {
    for kind in BOTH {
        let server = echo_server(kind);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let body = "{\"split\":true}";
        let req = format!(
            "POST /seg HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let bytes = req.as_bytes();
        // Dribble the request out in 5-byte chunks with pauses: the
        // parser must accumulate across reads without dropping state.
        for chunk in bytes.chunks(5) {
            s.write_all(chunk).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        let text = read_one_response(&mut s);
        assert!(text.starts_with("HTTP/1.1 200 OK"), "[{}] {text}", kind.name());
        assert!(text.contains(&format!("\"body_len\":{}", body.len())), "[{}] {text}", kind.name());
        server.stop();
    }
}

#[test]
fn accepts_bare_lf_line_endings() {
    // Hand-rolled clients (printf | nc) often send LF-only heads; the
    // old line-based parser accepted them, so keep doing so.
    for kind in BOTH {
        let server = echo_server(kind);
        let resp =
            raw_roundtrip(server.addr(), b"GET /lf?q=ok HTTP/1.1\nHost: x\nConnection: close\n\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "[{}] {resp}", kind.name());
        assert!(resp.contains("\"path\":\"/lf\""), "[{}] {resp}", kind.name());
        assert!(resp.contains("\"q\":\"ok\""), "[{}] {resp}", kind.name());
        server.stop();
    }
}

#[test]
fn accepts_lf_lines_with_crlf_blank() {
    for kind in BOTH {
        let server = echo_server(kind);
        let resp =
            raw_roundtrip(server.addr(), b"GET /mixed HTTP/1.1\nHost: x\nConnection: close\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "[{}] {resp}", kind.name());
        assert!(resp.contains("\"path\":\"/mixed\""), "[{}] {resp}", kind.name());
        server.stop();
    }
}

#[test]
fn rejects_malformed_request_line() {
    for kind in BOTH {
        let server = echo_server(kind);
        let resp = raw_roundtrip(server.addr(), b"NOT-HTTP\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "[{}] {resp}", kind.name());
        server.stop();
    }
}

#[test]
fn rejects_oversized_body_declaration() {
    for kind in BOTH {
        let server = echo_server(kind);
        let resp =
            raw_roundtrip(server.addr(), b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 413"), "[{}] {resp}", kind.name());
        server.stop();
    }
}

#[test]
fn rejects_conflicting_content_length() {
    for kind in BOTH {
        let server = echo_server(kind);
        let resp = raw_roundtrip(
            server.addr(),
            b"POST / HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 38\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "[{}] {resp}", kind.name());
        // Identical duplicates are mergeable per RFC 7230 and accepted.
        let resp = raw_roundtrip(
            server.addr(),
            b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nok",
        );
        assert!(resp.starts_with("HTTP/1.1 200"), "[{}] {resp}", kind.name());
        server.stop();
    }
}

#[test]
fn rejects_transfer_encoding_501() {
    for kind in BOTH {
        let server = echo_server(kind);
        let resp = raw_roundtrip(
            server.addr(),
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 501"), "[{}] {resp}", kind.name());
        server.stop();
    }
}

#[test]
fn rejects_oversized_headers_with_431() {
    for kind in BOTH {
        let server = echo_server(kind);
        let stats = server.stats();
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        req.extend_from_slice(b"X-Big: ");
        let padded = req.len() + MAX_HEADER_BYTES + 100;
        req.resize(padded, b'a');
        req.extend_from_slice(b"\r\n\r\n");
        let resp = raw_roundtrip(server.addr(), &req);
        assert!(resp.starts_with("HTTP/1.1 431"), "[{}] {resp}", kind.name());
        assert!(stats.rejected_431.load(Ordering::Relaxed) >= 1, "[{}]", kind.name());
        server.stop();
    }
}

#[test]
fn rejects_too_many_headers_with_431() {
    for kind in BOTH {
        let server = echo_server(kind);
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..(MAX_HEADERS + 8) {
            req.extend_from_slice(format!("X-H{i}: v\r\n").as_bytes());
        }
        req.extend_from_slice(b"\r\n");
        let resp = raw_roundtrip(server.addr(), &req);
        assert!(resp.starts_with("HTTP/1.1 431"), "[{}] {resp}", kind.name());
        server.stop();
    }
}

#[test]
fn steady_state_is_allocation_free() {
    for kind in BOTH {
        let server = echo_server(kind);
        let stats = server.stats();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let body = "{\"client_id\":\"warm\",\"app\":\"clomp\",\"alpha\":0.8,\"beta\":0.2}";
        let req = format!(
            "POST /v1/echo HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        // Warmup: let every buffer reach its high-water mark.
        for _ in 0..10 {
            s.write_all(req.as_bytes()).unwrap();
            read_one_response(&mut s);
        }
        let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
        let requests_before = stats.requests.load(Ordering::Relaxed);
        for _ in 0..200 {
            s.write_all(req.as_bytes()).unwrap();
            read_one_response(&mut s);
        }
        let allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
        let requests = stats.requests.load(Ordering::Relaxed) - requests_before;
        assert_eq!(requests, 200, "[{}]", kind.name());
        assert_eq!(
            allocs,
            0,
            "[{}] HTTP+JSON layers allocated {allocs} times over {requests} steady-state requests",
            kind.name()
        );
        server.stop();
    }
}

#[cfg(unix)]
#[test]
fn reactor_counts_open_connections_and_wakeups() {
    let server = echo_server(TransportKind::Reactor);
    let stats = server.stats();
    assert_eq!(stats.event_loops.load(Ordering::Relaxed), 2);
    let mut held = Vec::new();
    for _ in 0..8 {
        held.push(TcpStream::connect(server.addr()).unwrap());
    }
    // One round-trip forces the loops to have adopted everything that
    // was accepted before it.
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.write_all(b"GET /gauge HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    read_one_response(&mut s);
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while stats.conns_open.load(Ordering::Relaxed) < 9 {
        assert!(std::time::Instant::now() < deadline, "conns_open gauge never reached 9");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(stats.wakeups.load(Ordering::Relaxed) >= 1);
    drop(held);
    drop(s);
    // Closes are observed by readiness (EOF), so the gauge must fall
    // back to zero shortly after the clients disconnect.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while stats.conns_open.load(Ordering::Relaxed) > 0 {
        assert!(std::time::Instant::now() < deadline, "conns_open gauge never drained");
        std::thread::sleep(Duration::from_millis(10));
    }
    server.stop();
}
