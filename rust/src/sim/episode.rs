//! The episode stepper: one tuning loop for everything.
//!
//! An [`Episode`] drives *any* strategy (bandit policy or search baseline,
//! through the [`SearchStep`] interface) against *any* app model on *any*
//! device, with a declarative mid-episode [`Event`] schedule for
//! nonstationary scenarios: power-mode switches, noise bursts, shared-bus
//! interference from co-located tenants. Before this module existed the
//! same select → run → observe loop lived in four divergent places
//! (`harness::run_lasp`, `tuning::TuningSession`, the baselines' private
//! `EvalFn` loops, and the coordinator worker); they are all thin wrappers
//! over this stepper now.
//!
//! Determinism contract: an episode's entire behaviour is a function of
//! its inputs — app model, device seed, strategy seed, event schedule.
//! Nothing reads global mutable state, so identical episodes produce
//! bit-identical traces regardless of what runs on sibling threads
//! (asserted by `rust/tests/sim_engine.rs` at 1/4/8 threads).
//!
//! Steady-state steps are allocation-free: the strategy reuses the bandit
//! core's `Scratch`, recording buffers are preallocated to the episode
//! length, and the event schedule is applied by cursor
//! (`benches/sim_engine.rs` counts exact allocations per step).

use crate::apps::AppModel;
use crate::bandit::RegretTracker;
use crate::baselines::SearchStep;
use crate::chaos::sim::DeliveryChaos;
use crate::device::{Device, Measurement, NoiseModel, PowerMode};
use crate::telemetry::{ResourceReport, ResourceTracker};
use anyhow::Result;

/// A scheduled mid-episode environment change.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventAction {
    /// Switch the device's power mode in place (thermal and RNG state
    /// persist, like `nvpmodel -m` on a live board).
    SetMode(PowerMode),
    /// Replace the injected synthetic measurement error (noise bursts).
    SetNoise(NoiseModel),
    /// A co-located tenant saturates the shared memory bus: measured times
    /// stretch by `1 + slope · max(0, mem_intensity − threshold)`, which
    /// *reorders* the runtime ranking (the ablation's nonstationary mode).
    BusContention { slope: f64, threshold: f64 },
    /// The tenant leaves: end any bus contention.
    ClearContention,
    /// Session churn storm: from here on each measurement report is lost
    /// with probability `p` before the strategy sees it (clients vanishing
    /// mid-session). `p = 0` ends the storm.
    ChurnStorm { p: f64 },
    /// Duplicate delivery: each report reaches the strategy twice with
    /// probability `p` (an at-least-once transport re-sending). `p = 0`
    /// ends the fault.
    DuplicateReports { p: f64 },
    /// Skewed-popularity duplication: each report is re-delivered
    /// `rank − 1` extra times where `rank` is drawn from a Zipf(`s`)
    /// distribution — a heavy-tailed hot-key storm. `s ≤ 0` disables.
    ZipfDuplicates { s: f64 },
    /// Delayed delivery: reports are buffered and re-ordered, arriving
    /// 1..=`window`+1 iterations late. `window = 0` restores immediacy
    /// (already-buffered reports still drain on schedule).
    DelayReports { window: usize },
    /// Node kill: from the event's iteration until iteration `until` the
    /// node is down — nothing is selected or observed, the iteration
    /// budget still burns, and buffered in-flight reports are lost.
    Kill { until: usize },
}

/// An [`EventAction`] applied immediately before iteration `at` (0-based).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub at: usize,
    pub action: EventAction,
}

/// Episode run parameters: length plus what to record.
#[derive(Debug, Clone, Default)]
pub struct EpisodeSpec {
    /// Iteration budget `T`. The strategy may finish earlier (successive
    /// halving's ladder can converge).
    pub iterations: usize,
    /// Record the per-iteration arm sequence.
    pub record_trace: bool,
    /// Record per-iteration (arm, measurement) pairs.
    pub record_history: bool,
    /// Sample `/proc/self` per iteration (slow; single-session tooling
    /// like `lasp tune` wants it, sweeps do not).
    pub track_resources: bool,
    /// Per-arm expected rewards for cumulative-regret accounting (Fig 11).
    pub regret_mu: Option<Vec<f64>>,
    /// Seed for the delivery-chaos RNG (churn/duplicate/delay events).
    /// Only consumed when the schedule contains chaos events, so plain
    /// episodes are bit-identical to their pre-chaos behaviour.
    pub chaos_seed: u64,
}

/// What one [`Episode::step`] did.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub arm: usize,
    pub fidelity: f64,
    pub measurement: Measurement,
}

/// Everything an episode can report when it finishes.
#[derive(Debug, Clone)]
pub struct EpisodeOutcome {
    /// The strategy's recommendation (Eq. 4 for bandits; best-seen for
    /// search baselines).
    pub best_index: usize,
    /// Evaluations actually performed (≤ the iteration budget).
    pub evaluations: usize,
    /// Per-arm pull counts, when the strategy tracks them.
    pub counts: Option<Vec<f64>>,
    /// Arm sequence, if recording was enabled.
    pub trace: Option<Vec<usize>>,
    /// (arm, measurement) pairs, if recording was enabled.
    pub history: Option<Vec<(usize, Measurement)>>,
    /// Cumulative-regret trajectory, if a regret oracle was installed.
    pub regret: Option<Vec<f64>>,
    /// Total simulated seconds of application execution ("device time").
    pub simulated_device_seconds: f64,
    /// Wall-clock seconds the strategy itself spent selecting/updating.
    pub tuner_wall_seconds: f64,
    /// Process resource footprint, if tracking was enabled.
    pub resources: Option<ResourceReport>,
}

/// One tuning episode over borrowed parts. Borrowing (rather than owning)
/// lets `TuningSession`, the coordinator worker and the sweep runner all
/// assemble episodes from whatever they already own.
pub struct Episode<'a> {
    app: &'a dyn AppModel,
    device: &'a mut dyn Device,
    strategy: &'a mut dyn SearchStep,
    /// Event schedule, sorted by `at`.
    events: Vec<Event>,
    next_event: usize,
    contention: Option<(f64, f64)>,
    /// Delivery-chaos router, armed lazily by the first chaos event so
    /// chaos-free episodes never touch it (determinism + zero cost).
    chaos: Option<DeliveryChaos>,
    chaos_seed: u64,
    /// `Some(until)` while a [`EventAction::Kill`] window is open.
    kill_until: Option<usize>,
    t: usize,
    iterations: usize,
    done: bool,
    regret: Option<RegretTracker>,
    trace: Option<Vec<usize>>,
    history: Option<Vec<(usize, Measurement)>>,
    tracker: Option<ResourceTracker>,
    device_seconds: f64,
    tuner_seconds: f64,
}

impl<'a> Episode<'a> {
    pub fn new(
        app: &'a dyn AppModel,
        device: &'a mut dyn Device,
        strategy: &'a mut dyn SearchStep,
        events: &[Event],
        spec: &EpisodeSpec,
    ) -> Episode<'a> {
        let mut events = events.to_vec();
        events.sort_by_key(|e| e.at);
        Episode {
            app,
            device,
            strategy,
            events,
            next_event: 0,
            contention: None,
            chaos: None,
            chaos_seed: spec.chaos_seed,
            kill_until: None,
            t: 0,
            iterations: spec.iterations,
            done: false,
            regret: spec.regret_mu.clone().map(RegretTracker::new),
            trace: spec.record_trace.then(|| Vec::with_capacity(spec.iterations)),
            history: spec.record_history.then(|| Vec::with_capacity(spec.iterations)),
            tracker: spec.track_resources.then(ResourceTracker::start),
            device_seconds: 0.0,
            tuner_seconds: 0.0,
        }
    }

    /// Iterations executed so far.
    pub fn t(&self) -> usize {
        self.t
    }

    /// The strategy's current recommendation (live, mid-episode).
    pub fn recommend(&self) -> usize {
        self.strategy.recommend()
    }

    /// The strategy's per-arm pull counts, when it tracks them.
    pub fn counts(&self) -> Option<&[f64]> {
        self.strategy.counts()
    }

    /// Out-of-schedule power-mode switch (the coordinator worker reacts to
    /// leader messages this way; scripted scenarios use [`Event`]s).
    pub fn switch_mode(&mut self, mode: PowerMode) {
        self.device.switch_mode(mode);
    }

    /// The delivery-chaos router, armed on first use.
    fn chaos_mut(&mut self) -> &mut DeliveryChaos {
        if self.chaos.is_none() {
            self.chaos = Some(DeliveryChaos::new(self.chaos_seed));
        }
        self.chaos.as_mut().expect("just armed")
    }

    fn apply_events(&mut self) {
        while self.next_event < self.events.len() && self.events[self.next_event].at <= self.t {
            match self.events[self.next_event].action {
                EventAction::SetMode(mode) => self.device.switch_mode(mode),
                EventAction::SetNoise(noise) => self.device.set_injected_noise(noise),
                EventAction::BusContention { slope, threshold } => {
                    self.contention = Some((slope, threshold));
                }
                EventAction::ClearContention => self.contention = None,
                EventAction::ChurnStorm { p } => self.chaos_mut().set_churn(p),
                EventAction::DuplicateReports { p } => self.chaos_mut().set_dup(p),
                EventAction::ZipfDuplicates { s } => self.chaos_mut().set_zipf(s),
                EventAction::DelayReports { window } => self.chaos_mut().set_delay(window),
                EventAction::Kill { until } => self.kill_until = Some(until),
            }
            self.next_event += 1;
        }
    }

    /// Execute one select → run → observe round. Returns `None` once the
    /// iteration budget is spent or the strategy exhausted its schedule.
    pub fn step(&mut self) -> Result<Option<StepRecord>> {
        if self.done || self.t >= self.iterations {
            return Ok(None);
        }
        self.apply_events();

        // Open kill window: the node is down. The iteration budget still
        // burns, nothing is selected or observed, and whatever the delay
        // buffer held dies with the process.
        while let Some(until) = self.kill_until {
            if self.t >= until {
                self.kill_until = None;
                break;
            }
            if let Some(c) = &mut self.chaos {
                c.clear_in_flight();
            }
            self.t += 1;
            if self.t >= self.iterations {
                return Ok(None);
            }
            self.apply_events();
        }

        // Drain delayed reports that are due this iteration *before*
        // selecting, so the strategy decides on everything that has
        // arrived by now (matching a real async report pipeline).
        {
            let t = self.t;
            let (chaos, strategy) = (&mut self.chaos, &mut self.strategy);
            if let Some(c) = chaos.as_mut() {
                c.deliver_due(t, &mut |arm, fid, m| strategy.observe(arm, fid, m));
            }
        }

        let sel_start = std::time::Instant::now();
        let decision = self.strategy.next()?;
        self.tuner_seconds += sel_start.elapsed().as_secs_f64();
        let Some(d) = decision else {
            self.done = true;
            return Ok(None);
        };

        let fidelity = d.fidelity.unwrap_or_else(|| self.device.fidelity());
        let workload = self.app.workload(d.index, fidelity);
        let mut m = self.device.run(&workload);
        if let Some((slope, threshold)) = self.contention {
            m.time_s *= 1.0 + slope * (workload.mem_intensity - threshold).max(0.0);
        }
        self.device_seconds += m.time_s;

        let upd_start = std::time::Instant::now();
        {
            let t = self.t;
            let (chaos, strategy) = (&mut self.chaos, &mut self.strategy);
            match chaos.as_mut() {
                None => strategy.observe(d.index, fidelity, m),
                Some(c) => {
                    c.route(t, d.index, fidelity, m, &mut |arm, fid, mm| {
                        strategy.observe(arm, fid, mm)
                    });
                }
            }
        }
        self.tuner_seconds += upd_start.elapsed().as_secs_f64();

        if let Some(r) = &mut self.regret {
            r.record(d.index);
        }
        if let Some(tr) = &mut self.trace {
            tr.push(d.index);
        }
        if let Some(h) = &mut self.history {
            h.push((d.index, m));
        }
        if let Some(rt) = &mut self.tracker {
            rt.sample();
        }
        self.t += 1;
        Ok(Some(StepRecord { arm: d.index, fidelity, measurement: m }))
    }

    /// Run the remaining iterations and report.
    pub fn run(mut self) -> Result<EpisodeOutcome> {
        while self.step()?.is_some() {}
        Ok(self.finish())
    }

    /// Assemble the outcome from the current state (for manual-stepping
    /// drivers like the coordinator worker).
    pub fn finish(self) -> EpisodeOutcome {
        super::count_steps(self.t as u64);
        EpisodeOutcome {
            best_index: self.strategy.recommend(),
            evaluations: self.t,
            counts: self.strategy.counts().map(|c| c.to_vec()),
            trace: self.trace,
            history: self.history,
            regret: self.regret.map(|r| r.trajectory().to_vec()),
            simulated_device_seconds: self.device_seconds,
            tuner_wall_seconds: self.tuner_seconds,
            resources: self.tracker.map(|t| t.report()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{self, AppKind};
    use crate::device::JetsonNano;
    use crate::sim::strategy::PolicyStep;

    fn episode_outcome(events: &[Event], spec: &EpisodeSpec, seed: u64) -> EpisodeOutcome {
        let app = apps::build(AppKind::Clomp);
        let mut device = JetsonNano::new(PowerMode::Maxn, seed).with_fidelity(0.15);
        let mut policy = crate::bandit::UcbTuner::new(app.space().len(), 1.0, 0.0);
        let mut step = PolicyStep::new(&mut policy);
        Episode::new(app.as_ref(), &mut device, &mut step, events, spec)
            .run()
            .expect("episode")
    }

    #[test]
    fn plain_episode_matches_budget_and_counts() {
        let spec = EpisodeSpec { iterations: 200, record_trace: true, ..Default::default() };
        let out = episode_outcome(&[], &spec, 3);
        assert_eq!(out.evaluations, 200);
        assert_eq!(out.trace.as_ref().unwrap().len(), 200);
        let counts = out.counts.unwrap();
        assert_eq!(counts.iter().sum::<f64>(), 200.0);
        assert!(out.simulated_device_seconds > 0.0);
        assert!(out.history.is_none() && out.regret.is_none() && out.resources.is_none());
    }

    #[test]
    fn episodes_are_deterministic() {
        let spec = EpisodeSpec { iterations: 150, record_trace: true, ..Default::default() };
        let a = episode_outcome(&[], &spec, 9);
        let b = episode_outcome(&[], &spec, 9);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.best_index, b.best_index);
    }

    #[test]
    fn mode_switch_event_changes_the_tail() {
        let spec = EpisodeSpec { iterations: 120, record_history: true, ..Default::default() };
        let calm = episode_outcome(&[], &spec, 4);
        let switched = episode_outcome(
            &[Event { at: 60, action: EventAction::SetMode(PowerMode::FiveW) }],
            &spec,
            4,
        );
        let calm_h = calm.history.unwrap();
        let switched_h = switched.history.unwrap();
        // Identical prefix (same seed, same draws), diverging after the
        // switch: 5W runs are slower.
        assert_eq!(calm_h[..60], switched_h[..60]);
        let t = |h: &[(usize, Measurement)]| -> f64 {
            h[60..].iter().map(|(_, m)| m.time_s).sum::<f64>()
        };
        assert!(t(&switched_h) > t(&calm_h), "5W tail not slower");
        // Post-switch draws respect the 5W budget (modulo the board's
        // ±1.5% intrinsic measurement noise).
        for (_, m) in &switched_h[61..] {
            assert!(m.power_w <= 5.0 * 1.02, "power cap ignored after switch");
        }
    }

    #[test]
    fn bus_contention_stretches_memory_bound_time() {
        let spec = EpisodeSpec { iterations: 80, record_history: true, ..Default::default() };
        let calm = episode_outcome(&[], &spec, 5);
        let contended = episode_outcome(
            &[Event { at: 0, action: EventAction::BusContention { slope: 4.0, threshold: 0.0 } }],
            &spec,
            5,
        );
        let sum = |o: &EpisodeOutcome| {
            o.history.as_ref().unwrap().iter().map(|(_, m)| m.time_s).sum::<f64>()
        };
        assert!(sum(&contended) > sum(&calm) * 1.2);
        // Clearing restores the calm behaviour.
        let cleared = episode_outcome(
            &[
                Event { at: 0, action: EventAction::BusContention { slope: 4.0, threshold: 0.0 } },
                Event { at: 0, action: EventAction::ClearContention },
            ],
            &spec,
            5,
        );
        assert_eq!(sum(&cleared), sum(&calm));
    }

    #[test]
    fn noise_burst_event_applies() {
        let spec = EpisodeSpec { iterations: 100, record_history: true, ..Default::default() };
        let calm = episode_outcome(&[], &spec, 6);
        let bursty = episode_outcome(
            &[Event { at: 50, action: EventAction::SetNoise(NoiseModel::uniform(0.20)) }],
            &spec,
            6,
        );
        assert_eq!(
            calm.history.as_ref().unwrap()[..50],
            bursty.history.as_ref().unwrap()[..50]
        );
        assert_ne!(
            calm.history.as_ref().unwrap()[50..],
            bursty.history.as_ref().unwrap()[50..]
        );
    }

    #[test]
    fn regret_oracle_records_per_round() {
        let app = apps::build(AppKind::Clomp);
        let spec_dev = PowerMode::Maxn.spec();
        let sweep = crate::tuning::oracle_sweep(app.as_ref(), &spec_dev, 0.15);
        let mu = crate::tuning::expected_rewards(&sweep, 1.0, 0.0);
        let spec = EpisodeSpec { iterations: 90, regret_mu: Some(mu), ..Default::default() };
        let out = episode_outcome(&[], &spec, 7);
        let regret = out.regret.unwrap();
        assert_eq!(regret.len(), 90);
        assert!(regret.windows(2).all(|w| w[1] >= w[0] - 1e-9));
    }

    #[test]
    fn churn_storm_loses_every_observation() {
        let spec =
            EpisodeSpec { iterations: 60, record_trace: true, chaos_seed: 11, ..Default::default() };
        let out = episode_outcome(
            &[Event { at: 0, action: EventAction::ChurnStorm { p: 1.0 } }],
            &spec,
            8,
        );
        // Every report dropped before the strategy saw it: the episode
        // still ran its budget but the tuner recorded zero pulls.
        assert_eq!(out.trace.as_ref().unwrap().len(), 60);
        assert_eq!(out.counts.unwrap().iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn duplicate_reports_double_count_without_idempotency() {
        let spec = EpisodeSpec { iterations: 50, chaos_seed: 12, ..Default::default() };
        let out = episode_outcome(
            &[Event { at: 0, action: EventAction::DuplicateReports { p: 1.0 } }],
            &spec,
            8,
        );
        // The sim strategy has no sequence numbers, so an at-least-once
        // transport doubles its pull counts — the failure mode the serve
        // path's seq window exists to absorb.
        assert_eq!(out.counts.unwrap().iter().sum::<f64>(), 100.0);
    }

    #[test]
    fn delayed_reports_arrive_late_but_mostly_arrive() {
        let spec = EpisodeSpec { iterations: 60, chaos_seed: 13, ..Default::default() };
        let out = episode_outcome(
            &[Event { at: 0, action: EventAction::DelayReports { window: 4 } }],
            &spec,
            8,
        );
        let sum = out.counts.unwrap().iter().sum::<f64>();
        // Only the tail (due after the budget ends, ≤ window+1 reports)
        // can be lost.
        assert!((55.0..60.0).contains(&sum), "delayed delivery sum {sum}");
    }

    #[test]
    fn kill_window_burns_budget_without_observations() {
        let spec =
            EpisodeSpec { iterations: 50, record_trace: true, chaos_seed: 14, ..Default::default() };
        let out = episode_outcome(
            &[Event { at: 10, action: EventAction::Kill { until: 30 } }],
            &spec,
            8,
        );
        // 20 iterations burned while down: the budget is spent but only
        // 30 select/observe rounds happened.
        assert_eq!(out.evaluations, 50);
        assert_eq!(out.trace.as_ref().unwrap().len(), 30);
        assert_eq!(out.counts.unwrap().iter().sum::<f64>(), 30.0);
    }

    #[test]
    fn chaos_schedules_replay_bit_identically() {
        let events = [
            Event { at: 5, action: EventAction::ChurnStorm { p: 0.3 } },
            Event { at: 20, action: EventAction::DuplicateReports { p: 0.4 } },
            Event { at: 40, action: EventAction::DelayReports { window: 3 } },
            Event { at: 60, action: EventAction::Kill { until: 70 } },
        ];
        let spec =
            EpisodeSpec { iterations: 90, record_trace: true, chaos_seed: 21, ..Default::default() };
        let a = episode_outcome(&events, &spec, 9);
        let b = episode_outcome(&events, &spec, 9);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.best_index, b.best_index);
    }
}
