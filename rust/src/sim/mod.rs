//! The unified parallel scenario engine.
//!
//! One simulation core under everything that used to run its own loop:
//!
//! * [`episode`] — the single select → run → observe stepper
//!   ([`Episode`]) over borrowed app/device/strategy parts, with a
//!   declarative mid-episode [`Event`] schedule (power-mode switches,
//!   noise bursts, shared-bus contention);
//! * [`strategy`] — the declarative [`StrategySpec`] axis covering every
//!   bandit policy *and* every search baseline through the one
//!   [`crate::baselines::SearchStep`] interface;
//! * [`scenario`] — [`Scenario`] cells and the [`ScenarioGrid`] cross
//!   product, buildable from code or a `[sim]` TOML scenario file
//!   (`lasp simulate`);
//! * [`runner`] — the fixed-pool [`SweepRunner`] fanning cells out with
//!   deterministic, thread-count-independent result ordering, plus JSON
//!   emission;
//! * [`replay`] — the `replay` strategy: a recorded flight-recorder
//!   capture (`lasp loadgen --record`, `lasp serve --trace-file`) fed
//!   back through the episode engine as the decision-and-reward stream.
//!
//! Every figure driver, `tuning::TuningSession`, the coordinator worker
//! and the `lasp simulate` CLI are thin layers over this module; see
//! DESIGN.md §Simulation engine for the episode model, the determinism
//! contract and the scenario-file schema.

pub mod episode;
pub mod replay;
pub mod runner;
pub mod scenario;
pub mod strategy;

pub use episode::{Episode, EpisodeOutcome, EpisodeSpec, Event, EventAction, StepRecord};
pub use replay::ReplayStep;
pub use runner::{oracle_sweep_parallel, run_scenario, SweepResult, SweepRunner};
pub use scenario::{parse_events, Scenario, ScenarioGrid, DEFAULT_FIDELITY};
pub use strategy::{lasp_policy, Built, PolicyStep, StrategySpec};

use std::sync::atomic::{AtomicU64, Ordering};

static STEPS: AtomicU64 = AtomicU64::new(0);

/// Flush a finished episode's step count into the process-wide tally
/// (called once per episode, not per step, to keep the hot loop free of
/// shared-cacheline traffic).
pub(crate) fn count_steps(n: u64) {
    STEPS.fetch_add(n, Ordering::Relaxed);
}

/// Total episode steps executed by the engine in this process — the
/// steps/sec numerator for `lasp experiment`'s `BENCH_experiments.json`
/// and `benches/sim_engine.rs`.
pub fn steps_executed() -> u64 {
    STEPS.load(Ordering::Relaxed)
}
