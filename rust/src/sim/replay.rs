//! Trace replay: feed a recorded flight-recorder capture back through the
//! episode engine as the decision-and-reward stream.
//!
//! `lasp loadgen --record run.lasptrc` (or `lasp serve --trace-file`)
//! captures `Measure` events — `(app, mode, arm, time_s, power_w)` per
//! evaluation. A [`ReplayStep`] filters that capture down to one scenario
//! cell's `(app, mode)` and replays it through the same
//! [`SearchStep`](crate::baselines::SearchStep) interface every live
//! strategy uses: `next()` yields the recorded arm sequence in capture
//! order, `observe()` substitutes the *recorded* measurement for the sim
//! device's synthesized one, so the step's statistics reproduce what the
//! capture actually saw. Replay is pure data — no RNG — so a recorded run
//! replays bit-identically at any sweep thread count
//! (`rust/tests/trace_replay.rs`).
//!
//! Trace files are memoized process-wide by path: a grid fanning one
//! capture across many cells parses the file once.

use crate::apps::AppKind;
use crate::baselines::{Decision, SearchStep};
use crate::device::{Measurement, PowerMode};
use crate::obs::{self, TraceEvent};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

/// One recorded evaluation: the arm pulled and what the live run measured.
#[derive(Debug, Clone, Copy)]
struct Recorded {
    arm: usize,
    m: Measurement,
}

/// Process-wide memo of parsed trace files. The parse is a pure function
/// of the file contents, so caching cannot perturb determinism; concurrent
/// first loads are benign duplicated work resolving to the same value.
fn load_trace(path: &str) -> Result<Arc<Vec<TraceEvent>>> {
    static CACHE: OnceLock<Mutex<BTreeMap<String, Arc<Vec<TraceEvent>>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    if let Some(events) = cache.lock().expect("trace cache poisoned").get(path) {
        return Ok(events.clone());
    }
    let events = Arc::new(obs::read_trace_file(Path::new(path))?);
    Ok(cache
        .lock()
        .expect("trace cache poisoned")
        .entry(path.to_string())
        .or_insert(events)
        .clone())
}

/// A [`SearchStep`] that replays one `(app, mode)` slice of a recorded
/// trace: decisions come from the capture, and the capture's measurements
/// stand in for the sim device's as the observed reward stream.
pub struct ReplayStep {
    schedule: Vec<Recorded>,
    cursor: usize,
    /// The decision handed out by `next()`, consumed by the matching
    /// `observe()`.
    pending: Option<Recorded>,
    counts: Vec<f64>,
    time_sums: Vec<f64>,
    power_sums: Vec<f64>,
    alpha: f64,
    beta: f64,
}

impl ReplayStep {
    /// Load `path` and keep the `Measure` events matching `(app, mode)`,
    /// in capture order. Errors on an unreadable file, an empty slice
    /// (wrong cell for this capture), or an arm outside the app's space
    /// (a capture from a different parameter-space build).
    pub fn from_file(
        path: &str,
        app: AppKind,
        mode: PowerMode,
        k: usize,
        alpha: f64,
        beta: f64,
    ) -> Result<ReplayStep> {
        let events = load_trace(path)?;
        let mut schedule = Vec::new();
        for ev in events.iter() {
            let Some((a, m, arm, time_s, power_w)) = obs::decode_measure(ev) else {
                continue;
            };
            if a != app || m != mode {
                continue;
            }
            if arm >= k {
                return Err(anyhow!(
                    "trace {path}: recorded arm {arm} is outside {}'s {k}-arm space \
                     (capture from a different build?)",
                    app.name()
                ));
            }
            schedule.push(Recorded { arm, m: Measurement { time_s, power_w } });
        }
        if schedule.is_empty() {
            return Err(anyhow!(
                "trace {path} has no measurements for {}/{} — \
                 record with `lasp loadgen --record` covering that cell",
                app.name(),
                mode.lower_name()
            ));
        }
        Ok(ReplayStep {
            schedule,
            cursor: 0,
            pending: None,
            counts: vec![0.0; k],
            time_sums: vec![0.0; k],
            power_sums: vec![0.0; k],
            alpha,
            beta,
        })
    }

    /// Recorded evaluations available for this cell.
    pub fn len(&self) -> usize {
        self.schedule.len()
    }

    pub fn is_empty(&self) -> bool {
        self.schedule.is_empty()
    }
}

impl SearchStep for ReplayStep {
    fn next(&mut self) -> Result<Option<Decision>> {
        let Some(&r) = self.schedule.get(self.cursor) else {
            return Ok(None);
        };
        self.cursor += 1;
        self.pending = Some(r);
        Ok(Some(Decision::at_native(r.arm)))
    }

    fn observe(&mut self, index: usize, _fidelity: f64, live: Measurement) {
        // The capture is the reward stream: prefer the recorded
        // measurement over the sim device's synthesized one. The fallback
        // only fires for out-of-band observations a manual driver injects.
        let m = match self.pending.take() {
            Some(r) if r.arm == index => r.m,
            _ => live,
        };
        self.counts[index] += 1.0;
        self.time_sums[index] += m.time_s;
        self.power_sums[index] += m.power_w;
    }

    fn recommend(&self) -> usize {
        // Same Eq. 4 convention as the bandits: most-pulled arm,
        // ties to the lowest index.
        let mut best = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > self.counts[best] {
                best = i;
            }
        }
        best
    }

    fn best_objective(&self) -> f64 {
        let i = self.recommend();
        if self.counts[i] == 0.0 {
            return f64::INFINITY;
        }
        let n = self.counts[i];
        self.alpha * self.time_sums[i] / n + self.beta * self.power_sums[i] / n
    }

    fn counts(&self) -> Option<&[f64]> {
        Some(&self.counts)
    }

    fn name(&self) -> &'static str {
        "replay"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{pack_measure, EventKind};

    fn measure_event(seq: u64, app: AppKind, mode: PowerMode, arm: u32, t: f64, p: f64) -> TraceEvent {
        let (a, b, c) = pack_measure(app, mode, arm, t, p);
        TraceEvent { seq, t_us: seq * 10, kind: EventKind::Measure.code(), a, b, c }
    }

    fn write_fixture(path: &Path) {
        let events = vec![
            measure_event(0, AppKind::Clomp, PowerMode::Maxn, 3, 1.5, 6.0),
            measure_event(1, AppKind::Kripke, PowerMode::Maxn, 9, 9.0, 9.0),
            measure_event(2, AppKind::Clomp, PowerMode::Maxn, 3, 1.7, 6.2),
            measure_event(3, AppKind::Clomp, PowerMode::FiveW, 4, 2.5, 4.0),
            measure_event(4, AppKind::Clomp, PowerMode::Maxn, 1, 0.9, 5.5),
        ];
        obs::write_trace_file(path, &events).unwrap();
    }

    #[test]
    fn replays_only_the_matching_cell_in_capture_order() {
        let dir = std::env::temp_dir().join("lasp-replay-cell-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("capture.lasptrc");
        write_fixture(&path);
        let mut step = ReplayStep::from_file(
            path.to_str().unwrap(),
            AppKind::Clomp,
            PowerMode::Maxn,
            8,
            1.0,
            0.0,
        )
        .unwrap();
        assert_eq!(step.len(), 3);
        let mut arms = Vec::new();
        while let Some(d) = step.next().unwrap() {
            // A garbage live measurement must not leak into the stats.
            step.observe(d.index, 0.15, Measurement { time_s: 999.0, power_w: 999.0 });
            arms.push(d.index);
        }
        assert_eq!(arms, vec![3, 3, 1]);
        assert_eq!(step.recommend(), 3);
        // Mean recorded time of arm 3: (1.5 + 1.7) / 2.
        assert!((step.best_objective() - 1.6).abs() < 1e-12);
        assert_eq!(step.counts().unwrap()[3], 2.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_empty_slices_and_foreign_arms() {
        let dir = std::env::temp_dir().join("lasp-replay-reject-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("capture.lasptrc");
        write_fixture(&path);
        let p = path.to_str().unwrap();
        // No 5W Kripke measurements in the fixture.
        let err = ReplayStep::from_file(p, AppKind::Kripke, PowerMode::FiveW, 8, 1.0, 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("no measurements"), "{err}");
        // Kripke arm 9 does not fit a 4-arm space.
        let err = ReplayStep::from_file(p, AppKind::Kripke, PowerMode::Maxn, 4, 1.0, 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("outside"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_an_error() {
        let err = ReplayStep::from_file(
            "/nonexistent/lasp-no-such-capture.lasptrc",
            AppKind::Clomp,
            PowerMode::Maxn,
            8,
            1.0,
            0.0,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("lasp-no-such-capture"), "{err}");
    }
}
