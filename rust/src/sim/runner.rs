//! The sweep runner: fan a set of [`Scenario`] cells across a fixed thread
//! pool with deterministic result ordering, plus the single-cell executor
//! every wrapper (figures, `TuningSession`, `lasp simulate`) goes through.
//!
//! Determinism: cells are self-contained (own app model, own seeded
//! device, own seeded strategy), workers claim cell indices from an atomic
//! cursor, and results are reassembled by index — so the output is
//! bit-identical at any thread count (`rust/tests/sim_engine.rs` pins
//! 1 vs 4 vs 8 threads).

use super::episode::{Episode, EpisodeOutcome, EpisodeSpec};
use super::replay::ReplayStep;
use super::scenario::{Scenario, ScenarioGrid};
use super::strategy::StrategySpec;
use crate::apps::{self, AppKind, AppModel};
use crate::device::{DeviceSpec, JetsonNano, Measurement, PowerMode};
use crate::tuning::{expected_rewards, oracle_sweep};
use crate::util::json::JsonWriter;
use crate::util::stats;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Process-wide memo for regret-oracle tables: cells sharing an
/// (app, mode, fidelity, α, β) point reuse one noise-free sweep instead of
/// each recomputing it (at Hypre scale the 92,160-arm sweep costs more
/// than the episode it feeds). The table is a pure function of the key,
/// so caching cannot perturb determinism; concurrent first computations
/// are benign duplicated work resolving to the same value.
fn regret_mu_for(cell: &Scenario) -> Vec<f64> {
    type Key = (&'static str, &'static str, u64, u64, u64);
    static CACHE: OnceLock<Mutex<BTreeMap<Key, Vec<f64>>>> = OnceLock::new();
    let key = (
        cell.app.name(),
        cell.mode.name(),
        cell.fidelity.to_bits(),
        cell.alpha.to_bits(),
        cell.beta.to_bits(),
    );
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    if let Some(mu) = cache.lock().expect("mu cache poisoned").get(&key) {
        return mu.clone();
    }
    let app = apps::build(cell.app);
    let sweep = oracle_sweep(app.as_ref(), &cell.mode.spec(), cell.fidelity);
    let mu = expected_rewards(&sweep, cell.alpha, cell.beta);
    cache.lock().expect("mu cache poisoned").entry(key).or_insert(mu).clone()
}

/// Execute one scenario cell end to end: build the app model, the seeded
/// device and the seeded strategy, then drive one [`Episode`].
pub fn run_scenario(cell: &Scenario) -> Result<EpisodeOutcome> {
    let app = apps::build(cell.app);
    let k = app.space().len();
    let mut device = JetsonNano::new(cell.mode, cell.seed)
        .with_fidelity(cell.fidelity)
        .with_injected_noise(cell.noise);
    let regret_mu = cell.record_regret.then(|| regret_mu_for(cell));
    let spec = EpisodeSpec {
        iterations: cell.iterations,
        record_trace: cell.record_trace,
        record_history: false,
        track_resources: false,
        regret_mu,
        // Decorrelated from the device/strategy seed so chaos draws never
        // echo measurement noise, yet still a pure function of the cell.
        chaos_seed: cell.seed ^ 0x9E37_79B9_7F4A_7C15,
    };
    // Replay is built here, not in `StrategySpec::build`: only the
    // scenario carries the capture file it feeds from.
    if cell.strategy == StrategySpec::Replay {
        let path = cell.trace.as_deref().ok_or_else(|| {
            anyhow!("strategy 'replay' requires sim.trace = \"<capture file>\"")
        })?;
        let mut step =
            ReplayStep::from_file(path, cell.app, cell.mode, k, cell.alpha, cell.beta)?;
        return Episode::new(app.as_ref(), &mut device, &mut step, &cell.events, &spec).run();
    }
    let mut built = cell.strategy.build(k, cell.iterations, cell.alpha, cell.beta, cell.seed);
    let mut step = built.step(k, cell.iterations, cell.fidelity);
    Episode::new(app.as_ref(), &mut device, step.as_mut(), &cell.events, &spec).run()
}

/// A fixed-size thread pool for deterministic parallel sweeps.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    threads: usize,
}

impl SweepRunner {
    /// `threads == 0` sizes the pool from the host (`LASP_SIM_THREADS`
    /// overrides, then `available_parallelism`).
    pub fn new(threads: usize) -> SweepRunner {
        SweepRunner { threads }
    }

    fn pool_size(&self, jobs: usize) -> usize {
        let configured = if self.threads > 0 {
            self.threads
        } else {
            std::env::var("LASP_SIM_THREADS")
                .ok()
                .and_then(|v| v.parse().ok())
                .filter(|&n| n > 0)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
                })
        };
        configured.min(jobs).max(1)
    }

    /// Deterministic parallel map: `f(0..n)` on the pool, results in index
    /// order regardless of scheduling.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let threads = self.pool_size(n);
        if threads <= 1 {
            return (0..n).map(f).collect();
        }
        let cursor = AtomicUsize::new(0);
        let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    s.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("sweep worker panicked")).collect()
        });
        let mut merged: Vec<(usize, T)> = parts.into_iter().flatten().collect();
        merged.sort_by_key(|(i, _)| *i);
        merged.into_iter().map(|(_, t)| t).collect()
    }

    /// Run explicit cells (figure drivers build these), in cell order.
    pub fn run(&self, cells: &[Scenario]) -> Result<Vec<EpisodeOutcome>> {
        self.map(cells.len(), |i| run_scenario(&cells[i]))
            .into_iter()
            .collect()
    }

    /// Expand and run a grid.
    pub fn sweep(&self, grid: &ScenarioGrid) -> Result<SweepResult> {
        let cells = grid.cells();
        let outcomes = self.run(&cells)?;
        Ok(SweepResult { cells, outcomes })
    }
}

/// Noise-free per-arm (time, power) sweep parallelized over arm chunks —
/// the oracle table behind Figs 2/3/4/9/11, fanned over the pool for the
/// 92,160-arm Hypre space.
pub fn oracle_sweep_parallel(app: &dyn AppModel, spec: &DeviceSpec, q: f64) -> Vec<Measurement> {
    const CHUNK: usize = 4096;
    let k = app.space().len();
    // A single chunk degrades to a serial in-place map on the runner.
    let chunks = k.div_ceil(CHUNK);
    let parts = SweepRunner::new(0).map(chunks, |c| {
        let lo = c * CHUNK;
        let hi = (lo + CHUNK).min(k);
        (lo..hi)
            .map(|i| crate::device::run_with_cap(spec, &app.workload(i, q)))
            .collect::<Vec<_>>()
    });
    parts.into_iter().flatten().collect()
}

/// A completed sweep: cells paired with their outcomes, renderable as a
/// human table and as machine-readable JSON.
pub struct SweepResult {
    pub cells: Vec<Scenario>,
    pub outcomes: Vec<EpisodeOutcome>,
}

/// Oracle reference for one (app, mode, fidelity) point.
struct OracleRef {
    times: Vec<f64>,
    powers: Vec<f64>,
    default_index: usize,
}

impl OracleRef {
    /// §II-A oracle distance and Eq. 8 gain-vs-default on the objective's
    /// primary metric (time for α ≥ 0.5, else power), percent.
    fn scores(&self, best: usize, alpha: f64) -> (f64, f64) {
        let metric = if alpha >= 0.5 { &self.times } else { &self.powers };
        let oracle = metric[stats::argmin(metric)];
        let distance = (metric[best] / oracle - 1.0) * 100.0;
        let gain = (metric[self.default_index] - metric[best]) / metric[self.default_index] * 100.0;
        (distance, gain)
    }
}

impl SweepResult {
    fn oracle_key(c: &Scenario) -> (&'static str, &'static str, u64) {
        (c.app.name(), c.mode.name(), c.fidelity.to_bits())
    }

    fn oracle_refs(&self) -> BTreeMap<(&'static str, &'static str, u64), OracleRef> {
        let mut keys: Vec<(AppKind, PowerMode, f64)> = vec![];
        for c in &self.cells {
            if !keys
                .iter()
                .any(|(a, m, q)| *a == c.app && *m == c.mode && q.to_bits() == c.fidelity.to_bits())
            {
                keys.push((c.app, c.mode, c.fidelity));
            }
        }
        let refs = SweepRunner::new(0).map(keys.len(), |i| {
            let (app_kind, mode, q) = keys[i];
            let app = apps::build(app_kind);
            let sweep = oracle_sweep(app.as_ref(), &mode.spec(), q);
            OracleRef {
                times: sweep.iter().map(|m| m.time_s).collect(),
                powers: sweep.iter().map(|m| m.power_w).collect(),
                default_index: app.default_index(),
            }
        });
        keys.into_iter()
            .zip(refs)
            .map(|((a, m, q), r)| ((a.name(), m.name(), q.to_bits()), r))
            .collect()
    }

    /// Human-readable per-cell table.
    pub fn report(&self) {
        let oracles = self.oracle_refs();
        println!("\n## Scenario sweep — {} cells", self.cells.len());
        println!("| cell | best (Eq.4) | evals | oracle dist | gain vs default |");
        println!("|---|---|---|---|---|");
        for (c, o) in self.cells.iter().zip(&self.outcomes) {
            let oref = &oracles[&Self::oracle_key(c)];
            let (distance, gain) = oref.scores(o.best_index, c.alpha);
            println!(
                "| {} | #{} | {} | {:+.1}% | {:+.1}% |",
                c.label(),
                o.best_index,
                o.evaluations,
                distance,
                gain
            );
        }
    }

    /// Machine-readable JSON: per-cell best arm (index + description),
    /// oracle distance / gain vs default on the objective's primary
    /// metric, and the regret curve when recorded.
    pub fn to_json(&self) -> String {
        let oracles = self.oracle_refs();
        // One model per distinct app (describe() needs the space), not one
        // per cell.
        let mut models: BTreeMap<&'static str, Box<dyn AppModel>> = BTreeMap::new();
        for c in &self.cells {
            models.entry(c.app.name()).or_insert_with(|| apps::build(c.app));
        }
        let mut buf = Vec::with_capacity(4096);
        let mut w = JsonWriter::new(&mut buf);
        w.begin_obj();
        w.field_str("engine", "lasp-sim");
        w.field_num("cells", self.cells.len() as f64);
        w.key("results");
        w.begin_arr();
        for (c, o) in self.cells.iter().zip(&self.outcomes) {
            let app = &models[c.app.name()];
            let oref = &oracles[&Self::oracle_key(c)];
            let (distance, gain) = oref.scores(o.best_index, c.alpha);
            w.begin_obj();
            w.field_str("app", c.app.name());
            w.field_str("mode", c.mode.lower_name());
            w.field_str("strategy", &c.strategy.label());
            w.field_num("alpha", c.alpha);
            w.field_num("beta", c.beta);
            w.field_num("seed", c.seed as f64);
            w.field_num("iterations", c.iterations as f64);
            w.field_num("noise_pct", c.noise.pct);
            w.field_num("events", c.events.len() as f64);
            w.field_num("best_index", o.best_index as f64);
            w.field_str("best_config", &app.space().describe(o.best_index));
            w.field_num("evaluations", o.evaluations as f64);
            w.field_num("oracle_distance_pct", distance);
            w.field_num("gain_vs_default_pct", gain);
            w.field_num("simulated_device_seconds", o.simulated_device_seconds);
            if let Some(regret) = &o.regret {
                w.key("regret");
                w.begin_arr();
                for r in regret {
                    w.num_val(*r);
                }
                w.end_arr();
            }
            if let Some(trace) = &o.trace {
                w.key("trace");
                w.begin_arr();
                for arm in trace {
                    w.num_val(*arm as f64);
                }
                w.end_arr();
            }
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
        String::from_utf8(buf).expect("sweep JSON is UTF-8")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::StrategySpec;
    use crate::util::json::Json;

    #[test]
    fn map_preserves_index_order() {
        for threads in [1, 3, 8] {
            let out = SweepRunner::new(threads).map(23, |i| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>());
        }
        assert!(SweepRunner::new(4).map(0, |i| i).is_empty());
    }

    #[test]
    fn run_scenario_matches_direct_episode() {
        let cell = Scenario::lasp(AppKind::Clomp, PowerMode::Maxn, 120, 3)
            .with_objective(1.0, 0.0)
            .recording_trace();
        let a = run_scenario(&cell).unwrap();
        let b = run_scenario(&cell).unwrap();
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.best_index, b.best_index);
        assert_eq!(a.evaluations, 120);
    }

    #[test]
    fn sweep_emits_valid_json() {
        let grid = ScenarioGrid {
            apps: vec![AppKind::Clomp],
            strategies: vec![StrategySpec::Ucb, StrategySpec::Random],
            seeds: vec![1, 2],
            iterations: 80,
            record_regret: true,
            ..Default::default()
        };
        let result = SweepRunner::new(2).sweep(&grid).unwrap();
        assert_eq!(result.outcomes.len(), 4);
        let json = result.to_json();
        let parsed = Json::parse(&json).expect("valid JSON");
        let cells = parsed.get("results").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(cells.len(), 4);
        for cell in cells {
            assert!(cell.get("best_index").and_then(|v| v.as_f64()).is_some());
            assert_eq!(
                cell.get("regret").and_then(|r| r.as_arr()).map(|a| a.len()),
                Some(80)
            );
        }
    }

    #[test]
    fn parallel_oracle_sweep_matches_serial() {
        // Hypre's 92,160 arms exercise the chunked path (>1 chunk).
        let app = apps::build(AppKind::Hypre);
        let spec = PowerMode::Maxn.spec();
        let serial = oracle_sweep(app.as_ref(), &spec, 0.15);
        let parallel = oracle_sweep_parallel(app.as_ref(), &spec, 0.15);
        assert_eq!(serial, parallel);
    }
}
