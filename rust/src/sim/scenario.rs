//! Declarative scenarios: one [`Scenario`] is a fully-specified episode
//! (app × device mode × noise × objective × strategy × seed × events); a
//! [`ScenarioGrid`] is the cross product the sweep runner fans out.
//!
//! Grids are buildable from code (the figure drivers declare them) or from
//! a TOML scenario file with a `[sim]` section — see `DESIGN.md`
//! §Simulation engine for the schema and `docs/scenarios/` for runnable
//! examples (`lasp simulate --scenario <file>`).

use super::episode::{Event, EventAction};
use super::strategy::StrategySpec;
use crate::apps::AppKind;
use crate::config::parse_toml;
use crate::device::{NoiseModel, PowerMode};
use anyhow::{anyhow, Context, Result};

/// Default low-fidelity evaluation point on the edge device (paper §II-C).
pub const DEFAULT_FIDELITY: f64 = 0.15;

/// One fully-specified episode cell.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub app: AppKind,
    pub mode: PowerMode,
    pub iterations: usize,
    pub alpha: f64,
    pub beta: f64,
    pub seed: u64,
    pub fidelity: f64,
    /// Injected synthetic measurement error (Fig 12 studies).
    pub noise: NoiseModel,
    pub strategy: StrategySpec,
    /// Mid-episode environment changes.
    pub events: Vec<Event>,
    pub record_trace: bool,
    pub record_regret: bool,
    /// Recorded flight-recorder capture consumed by the `replay` strategy.
    pub trace: Option<String>,
}

impl Scenario {
    /// A LASP cell with the defaults every figure driver shares.
    pub fn lasp(app: AppKind, mode: PowerMode, iterations: usize, seed: u64) -> Scenario {
        Scenario {
            app,
            mode,
            iterations,
            alpha: 0.8,
            beta: 0.2,
            seed,
            fidelity: DEFAULT_FIDELITY,
            noise: NoiseModel::none(),
            strategy: StrategySpec::Lasp,
            events: vec![],
            record_trace: false,
            record_regret: false,
            trace: None,
        }
    }

    pub fn with_objective(mut self, alpha: f64, beta: f64) -> Scenario {
        self.alpha = alpha;
        self.beta = beta;
        self
    }

    pub fn with_noise(mut self, noise: NoiseModel) -> Scenario {
        self.noise = noise;
        self
    }

    pub fn with_strategy(mut self, strategy: StrategySpec) -> Scenario {
        self.strategy = strategy;
        self
    }

    pub fn with_events(mut self, events: Vec<Event>) -> Scenario {
        self.events = events;
        self
    }

    pub fn recording_trace(mut self) -> Scenario {
        self.record_trace = true;
        self
    }

    pub fn recording_regret(mut self) -> Scenario {
        self.record_regret = true;
        self
    }

    /// Attach the capture file the `replay` strategy feeds back.
    pub fn with_trace(mut self, path: &str) -> Scenario {
        self.trace = Some(path.to_string());
        self
    }

    /// Compact cell label for reports.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/α{:.2}/{}/seed{}",
            self.app,
            self.mode.lower_name(),
            self.alpha,
            self.strategy.label(),
            self.seed
        )
    }
}

/// A declarative cross product of scenario axes.
#[derive(Debug, Clone)]
pub struct ScenarioGrid {
    pub apps: Vec<AppKind>,
    pub modes: Vec<PowerMode>,
    /// Injected-noise percentages (0.0 = clean).
    pub noise_pcts: Vec<f64>,
    /// (α, β) objective pairs.
    pub objectives: Vec<(f64, f64)>,
    pub strategies: Vec<StrategySpec>,
    pub seeds: Vec<u64>,
    pub iterations: usize,
    pub fidelity: f64,
    /// Event schedule shared by every cell.
    pub events: Vec<Event>,
    pub record_trace: bool,
    pub record_regret: bool,
    /// Capture file shared by every `replay` cell.
    pub trace: Option<String>,
}

impl Default for ScenarioGrid {
    fn default() -> Self {
        ScenarioGrid {
            apps: AppKind::all().to_vec(),
            modes: vec![PowerMode::Maxn],
            noise_pcts: vec![0.0],
            objectives: vec![(0.8, 0.2)],
            strategies: vec![StrategySpec::Lasp],
            seeds: vec![42],
            iterations: 500,
            fidelity: DEFAULT_FIDELITY,
            events: vec![],
            record_trace: false,
            record_regret: false,
            trace: None,
        }
    }
}

impl ScenarioGrid {
    /// Expand the cross product in a fixed deterministic order:
    /// app → mode → noise → objective → strategy → seed.
    pub fn cells(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        for &app in &self.apps {
            for &mode in &self.modes {
                for &pct in &self.noise_pcts {
                    let noise =
                        if pct > 0.0 { NoiseModel::uniform(pct) } else { NoiseModel::none() };
                    for &(alpha, beta) in &self.objectives {
                        for &strategy in &self.strategies {
                            for &seed in &self.seeds {
                                out.push(Scenario {
                                    app,
                                    mode,
                                    iterations: self.iterations,
                                    alpha,
                                    beta,
                                    seed,
                                    fidelity: self.fidelity,
                                    noise,
                                    strategy,
                                    events: self.events.clone(),
                                    record_trace: self.record_trace,
                                    record_regret: self.record_regret,
                                    trace: self.trace.clone(),
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Number of cells in the cross product.
    pub fn len(&self) -> usize {
        self.apps.len()
            * self.modes.len()
            * self.noise_pcts.len()
            * self.objectives.len()
            * self.strategies.len()
            * self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Load a grid from a TOML scenario file.
    pub fn from_file(path: &std::path::Path) -> Result<ScenarioGrid> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml_str(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse the `[sim]` section of a scenario file. List-valued keys are
    /// comma-separated strings (the config parser's TOML subset has no
    /// arrays); see DESIGN.md §Simulation engine for the full schema.
    pub fn from_toml_str(text: &str) -> Result<ScenarioGrid> {
        let doc = parse_toml(text).map_err(|e| anyhow!("scenario parse: {e}"))?;
        let Some(sim) = doc.get("sim") else {
            return Err(anyhow!("scenario file has no [sim] section"));
        };
        let mut grid = ScenarioGrid::default();

        let str_of = |key: &str| -> Result<Option<&str>> {
            match sim.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_str()
                    .map(Some)
                    .ok_or_else(|| anyhow!("sim.{key} must be a string")),
            }
        };
        if let Some(s) = str_of("apps")? {
            grid.apps = if s.trim() == "all" {
                AppKind::all().to_vec()
            } else {
                split_list(s).map(str::parse).collect::<Result<Vec<_>>>()?
            };
        }
        if let Some(s) = str_of("modes")? {
            grid.modes = if s.trim() == "all" {
                vec![PowerMode::Maxn, PowerMode::FiveW]
            } else {
                split_list(s).map(str::parse).collect::<Result<Vec<_>>>()?
            };
        }
        if let Some(s) = str_of("noise")? {
            grid.noise_pcts = split_list(s)
                .map(|x| x.parse::<f64>().map_err(|_| anyhow!("sim.noise: bad value '{x}'")))
                .collect::<Result<Vec<_>>>()?;
            if grid.noise_pcts.iter().any(|p| !(0.0..1.0).contains(p)) {
                return Err(anyhow!("sim.noise values must lie in [0, 1)"));
            }
        }
        if let Some(s) = str_of("objectives")? {
            grid.objectives = split_list(s).map(parse_objective).collect::<Result<Vec<_>>>()?;
        }
        if let Some(s) = str_of("strategies")? {
            grid.strategies =
                split_list(s).map(StrategySpec::parse).collect::<Result<Vec<_>>>()?;
        }
        if let Some(s) = str_of("seeds")? {
            grid.seeds = parse_seeds(s)?;
        }
        if let Some(v) = sim.get("iterations") {
            let i = v.as_int().ok_or_else(|| anyhow!("sim.iterations must be int"))?;
            if !(1..=10_000_000).contains(&i) {
                return Err(anyhow!("sim.iterations must lie in 1..=10000000, got {i}"));
            }
            grid.iterations = i as usize;
        }
        if let Some(v) = sim.get("fidelity") {
            let q = v.as_float().ok_or_else(|| anyhow!("sim.fidelity must be number"))?;
            if !(0.0..=1.0).contains(&q) {
                return Err(anyhow!("sim.fidelity must lie in [0, 1]"));
            }
            grid.fidelity = q;
        }
        if let Some(v) = sim.get("record_trace") {
            grid.record_trace =
                v.as_bool().ok_or_else(|| anyhow!("sim.record_trace must be bool"))?;
        }
        if let Some(v) = sim.get("record_regret") {
            grid.record_regret =
                v.as_bool().ok_or_else(|| anyhow!("sim.record_regret must be bool"))?;
        }
        if let Some(s) = str_of("events")? {
            grid.events = parse_events(s)?;
        }
        if let Some(s) = str_of("trace")? {
            grid.trace = Some(s.trim().to_string());
        }
        if grid.strategies.contains(&StrategySpec::Replay) && grid.trace.is_none() {
            return Err(anyhow!("strategy 'replay' requires sim.trace = \"<capture file>\""));
        }
        if grid.is_empty() {
            return Err(anyhow!("scenario grid is empty (an axis has no values)"));
        }
        Ok(grid)
    }
}

fn split_list(s: &str) -> impl Iterator<Item = &str> {
    s.split(',').map(str::trim).filter(|x| !x.is_empty())
}

/// `"0.8:0.2"` → (α, β).
fn parse_objective(s: &str) -> Result<(f64, f64)> {
    let (a, b) = s
        .split_once(':')
        .ok_or_else(|| anyhow!("objective '{s}' must be alpha:beta (e.g. 0.8:0.2)"))?;
    let alpha: f64 = a.trim().parse().map_err(|_| anyhow!("bad alpha '{a}'"))?;
    let beta: f64 = b.trim().parse().map_err(|_| anyhow!("bad beta '{b}'"))?;
    if !(0.0..=1.0).contains(&alpha) || !(0.0..=1.0).contains(&beta) || alpha + beta == 0.0 {
        return Err(anyhow!("objective '{s}': weights must lie in [0,1], not both zero"));
    }
    Ok((alpha, beta))
}

/// `"1,2,9"` or the half-open range `"900..905"`.
fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    if let Some((lo, hi)) = s.split_once("..") {
        let lo: u64 = lo.trim().parse().map_err(|_| anyhow!("bad seed range start '{lo}'"))?;
        let hi: u64 = hi.trim().parse().map_err(|_| anyhow!("bad seed range end '{hi}'"))?;
        if hi <= lo || hi - lo > 100_000 {
            return Err(anyhow!("seed range {lo}..{hi} must be ascending and modest"));
        }
        return Ok((lo..hi).collect());
    }
    split_list(s)
        .map(|x| x.parse::<u64>().map_err(|_| anyhow!("bad seed '{x}'")))
        .collect()
}

/// Event DSL: comma-separated `action@iteration[=arg]` items.
///
/// * `mode@250=5w` — switch the power mode at iteration 250;
/// * `noise@300=0.15` — inject 15% uniform measurement error from 300 on
///   (`=0` ends a burst);
/// * `bus@600=4x0.45` — bus contention with slope 4 above memory-intensity
///   threshold 0.45;
/// * `clear@800` — end the bus contention.
///
/// Chaos actions (seeded from the cell, replayable at any thread count):
///
/// * `churn@100=0.3` — session churn storm: each report lost with
///   probability 0.3 (`=0` ends the storm);
/// * `dup@200=0.5` — duplicate delivery with probability 0.5 per report;
/// * `zipf@300=1.2` — skewed-popularity re-delivery, Zipf exponent 1.2
///   (`s` in (0, 8]);
/// * `delay@400=4` — buffer and reorder reports, arriving 1..=5
///   iterations late (`=0` restores immediate delivery);
/// * `kill@500=550` — node down from iteration 500 until 550 (budget
///   burns, nothing selected or observed, in-flight reports lost).
pub fn parse_events(s: &str) -> Result<Vec<Event>> {
    split_list(s).map(parse_event).collect()
}

/// Parse a probability-valued chaos arg in [0, 1).
fn chaos_prob(s: &str, arg: &str) -> Result<f64> {
    let p: f64 = arg.parse().map_err(|_| anyhow!("event '{s}': bad probability '{arg}'"))?;
    if !(0.0..1.0).contains(&p) {
        return Err(anyhow!("event '{s}': probability must lie in [0, 1)"));
    }
    Ok(p)
}

fn parse_event(s: &str) -> Result<Event> {
    let (head, arg) = match s.split_once('=') {
        Some((h, a)) => (h.trim(), Some(a.trim())),
        None => (s.trim(), None),
    };
    let (kind, at) = head
        .split_once('@')
        .ok_or_else(|| anyhow!("event '{s}' must be action@iteration[=arg]"))?;
    let at: usize = at
        .trim()
        .parse()
        .map_err(|_| anyhow!("event '{s}': bad iteration '{at}'"))?;
    let need = |what: &str| -> Result<&str> {
        arg.ok_or_else(|| anyhow!("event '{s}' needs ={what}"))
    };
    let action = match kind.trim() {
        "mode" => EventAction::SetMode(need("mode")?.parse()?),
        "noise" => {
            let pct: f64 = need("pct")?
                .parse()
                .map_err(|_| anyhow!("event '{s}': bad noise pct"))?;
            if !(0.0..1.0).contains(&pct) {
                return Err(anyhow!("event '{s}': noise pct must lie in [0, 1)"));
            }
            let noise = if pct > 0.0 { NoiseModel::uniform(pct) } else { NoiseModel::none() };
            EventAction::SetNoise(noise)
        }
        "bus" => {
            let spec = need("slope x threshold")?;
            let (slope, threshold) = spec
                .split_once('x')
                .ok_or_else(|| anyhow!("event '{s}': bus arg must be <slope>x<threshold>"))?;
            let slope: f64 =
                slope.trim().parse().map_err(|_| anyhow!("event '{s}': bad slope"))?;
            let threshold: f64 =
                threshold.trim().parse().map_err(|_| anyhow!("event '{s}': bad threshold"))?;
            if slope < 0.0 || !(0.0..=1.0).contains(&threshold) {
                return Err(anyhow!("event '{s}': slope >= 0, threshold in [0, 1]"));
            }
            EventAction::BusContention { slope, threshold }
        }
        "clear" => EventAction::ClearContention,
        "churn" => EventAction::ChurnStorm { p: chaos_prob(s, need("probability")?)? },
        "dup" => EventAction::DuplicateReports { p: chaos_prob(s, need("probability")?)? },
        "zipf" => {
            let exp: f64 = need("exponent")?
                .parse()
                .map_err(|_| anyhow!("event '{s}': bad zipf exponent"))?;
            if !(0.0..=8.0).contains(&exp) {
                return Err(anyhow!("event '{s}': zipf exponent must lie in [0, 8] (0 disables)"));
            }
            EventAction::ZipfDuplicates { s: exp }
        }
        "delay" => {
            let window: usize = need("window")?
                .parse()
                .map_err(|_| anyhow!("event '{s}': bad delay window"))?;
            if window > 10_000 {
                return Err(anyhow!("event '{s}': delay window must be <= 10000"));
            }
            EventAction::DelayReports { window }
        }
        "kill" => {
            let until: usize = need("until")?
                .parse()
                .map_err(|_| anyhow!("event '{s}': bad kill end iteration"))?;
            if until <= at {
                return Err(anyhow!("event '{s}': kill end {until} must be > start {at}"));
            }
            EventAction::Kill { until }
        }
        other => {
            return Err(anyhow!(
                "event '{s}': unknown action '{other}' \
                 (mode|noise|bus|clear|churn|dup|zipf|delay|kill)"
            ))
        }
    };
    Ok(Event { at, action })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_covers_all_apps() {
        let g = ScenarioGrid::default();
        assert_eq!(g.len(), 4);
        let cells = g.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].app, AppKind::Lulesh);
        assert!(cells.iter().all(|c| c.iterations == 500));
    }

    #[test]
    fn cell_order_is_the_documented_cross_product() {
        let g = ScenarioGrid {
            apps: vec![AppKind::Clomp, AppKind::Kripke],
            objectives: vec![(1.0, 0.0), (0.0, 1.0)],
            seeds: vec![1, 2],
            ..Default::default()
        };
        let cells = g.cells();
        assert_eq!(cells.len(), 8);
        // app is the slowest axis, seed the fastest.
        assert_eq!((cells[0].app, cells[0].alpha, cells[0].seed), (AppKind::Clomp, 1.0, 1));
        assert_eq!((cells[1].app, cells[1].alpha, cells[1].seed), (AppKind::Clomp, 1.0, 2));
        assert_eq!((cells[2].app, cells[2].alpha, cells[2].seed), (AppKind::Clomp, 0.0, 1));
        assert_eq!((cells[4].app, cells[4].alpha, cells[4].seed), (AppKind::Kripke, 1.0, 1));
    }

    #[test]
    fn parses_full_scenario_file() {
        let g = ScenarioGrid::from_toml_str(
            r#"
            # A nonstationary sweep the seed-era loops could not express.
            [sim]
            apps = "all"
            modes = "maxn"
            noise = "0, 0.05"
            objectives = "0.8:0.2, 0.2:0.8"
            strategies = "lasp, swucb:600"
            seeds = "900..903"
            iterations = 800
            fidelity = 0.15
            record_trace = true
            events = "mode@400=5w, noise@500=0.15, noise@700=0, bus@600=4x0.45, clear@750"
            "#,
        )
        .unwrap();
        // 4 apps × 1 mode × 2 noises × 2 objectives × 2 strategies × 3 seeds
        assert_eq!(g.len(), 96);
        assert_eq!(g.iterations, 800);
        assert_eq!(g.seeds, vec![900, 901, 902]);
        assert_eq!(g.events.len(), 5);
        assert_eq!(
            g.events[0],
            Event { at: 400, action: EventAction::SetMode(PowerMode::FiveW) }
        );
        assert_eq!(
            g.events[3],
            Event { at: 600, action: EventAction::BusContention { slope: 4.0, threshold: 0.45 } }
        );
        assert_eq!(g.events[4], Event { at: 750, action: EventAction::ClearContention });
        assert!(g.record_trace && !g.record_regret);
    }

    #[test]
    fn rejects_malformed_scenarios() {
        assert!(ScenarioGrid::from_toml_str("[tune]\napp = \"kripke\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\napps = \"doom\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\nobjectives = \"0.8\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\nstrategies = \"sgd\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\nseeds = \"9..3\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\nnoise = \"1.5\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\nevents = \"warp@3\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\nevents = \"mode@x=5w\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\napps = \",\"\n").is_err());
        assert!(ScenarioGrid::from_toml_str("[sim]\niterations = 0\n").is_err());
        // Replay without a capture file is a parse-time error.
        assert!(ScenarioGrid::from_toml_str("[sim]\nstrategies = \"replay\"\n").is_err());
    }

    #[test]
    fn parses_chaos_events() {
        let events = parse_events(
            "churn@100=0.3, dup@200=0.5, zipf@300=1.2, delay@400=4, kill@500=550, churn@600=0",
        )
        .unwrap();
        assert_eq!(events.len(), 6);
        assert_eq!(events[0], Event { at: 100, action: EventAction::ChurnStorm { p: 0.3 } });
        assert_eq!(events[1], Event { at: 200, action: EventAction::DuplicateReports { p: 0.5 } });
        assert_eq!(events[2], Event { at: 300, action: EventAction::ZipfDuplicates { s: 1.2 } });
        assert_eq!(events[3], Event { at: 400, action: EventAction::DelayReports { window: 4 } });
        assert_eq!(events[4], Event { at: 500, action: EventAction::Kill { until: 550 } });
        assert_eq!(events[5], Event { at: 600, action: EventAction::ChurnStorm { p: 0.0 } });
    }

    #[test]
    fn rejects_malformed_chaos_events() {
        // Probabilities must lie in [0, 1); 1.0 would drop everything forever.
        assert!(parse_events("churn@10=1.0").is_err());
        assert!(parse_events("dup@10=-0.1").is_err());
        assert!(parse_events("churn@10").is_err());
        // Zipf exponent bounded; delay window bounded; kill must end later.
        assert!(parse_events("zipf@10=9.0").is_err());
        assert!(parse_events("delay@10=20000").is_err());
        assert!(parse_events("kill@50=50").is_err());
        assert!(parse_events("kill@50=10").is_err());
        assert!(parse_events("kill@50").is_err());
    }

    #[test]
    fn replay_grid_carries_its_trace_file() {
        let g = ScenarioGrid::from_toml_str(
            "[sim]\nstrategies = \"replay\"\ntrace = \"runs/capture.lasptrc\"\n",
        )
        .unwrap();
        assert_eq!(g.strategies, vec![StrategySpec::Replay]);
        assert_eq!(g.trace.as_deref(), Some("runs/capture.lasptrc"));
        assert!(g.cells().iter().all(|c| c.trace.as_deref() == Some("runs/capture.lasptrc")));
    }

    #[test]
    fn scenario_builders_compose() {
        let s = Scenario::lasp(AppKind::Hypre, PowerMode::FiveW, 300, 7)
            .with_objective(0.2, 0.8)
            .with_noise(NoiseModel::uniform(0.1))
            .with_strategy(StrategySpec::Thompson)
            .with_events(parse_events("mode@100=maxn").unwrap())
            .with_trace("runs/capture.lasptrc")
            .recording_trace()
            .recording_regret();
        assert_eq!(s.alpha, 0.2);
        assert_eq!(s.strategy, StrategySpec::Thompson);
        assert_eq!(s.events.len(), 1);
        assert!(s.record_trace && s.record_regret);
        assert_eq!(s.trace.as_deref(), Some("runs/capture.lasptrc"));
        assert!(s.label().contains("hypre"));
        assert!(s.label().contains("thompson"));
    }
}
