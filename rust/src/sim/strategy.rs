//! Strategy layer of the scenario engine: one declarative [`StrategySpec`]
//! naming every tuner this system knows — the five bandit policies *and*
//! the four search baselines — plus the [`PolicyStep`] adapter that lets a
//! bandit [`Policy`] ride the same incremental
//! [`SearchStep`](crate::baselines::SearchStep) interface the baselines
//! expose. This is what collapses the seed-era per-family run loops into
//! one episode stepper.

use crate::bandit::{
    EpsilonGreedy, Policy, SlidingWindowUcb, SubsetTuner, ThompsonSampler, UcbTuner,
};
use crate::baselines::{
    BlissBo, Decision, RandomSearch, SearchStep, Searcher, SimulatedAnnealing, SuccessiveHalving,
};
use crate::device::Measurement;
use anyhow::{anyhow, Result};

/// Build the LASP policy for a space of size `k`: plain UCB1 when the
/// budget covers the init sweep, candidate-subset LASP otherwise
/// (paper §IV-B scalability adaptation — see `bandit::subset`).
pub fn lasp_policy(
    k: usize,
    iterations: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
) -> Box<dyn Policy> {
    if k > iterations / 2 && k > 256 {
        let m = SubsetTuner::recommended_size(k, iterations);
        Box::new(SubsetTuner::new(k, m, alpha, beta, seed ^ 0xA5A5))
    } else {
        Box::new(UcbTuner::new(k, alpha, beta))
    }
}

/// Adapter: any bandit [`Policy`] driven through the incremental
/// [`SearchStep`] interface. Selection is allocation-free in steady state
/// (the policy's own `Scratch` is reused underneath).
pub struct PolicyStep<'a> {
    policy: &'a mut dyn Policy,
}

impl<'a> PolicyStep<'a> {
    pub fn new(policy: &'a mut dyn Policy) -> PolicyStep<'a> {
        PolicyStep { policy }
    }
}

impl SearchStep for PolicyStep<'_> {
    fn next(&mut self) -> Result<Option<Decision>> {
        Ok(Some(Decision::at_native(self.policy.select())))
    }

    fn observe(&mut self, index: usize, _fidelity: f64, m: Measurement) {
        self.policy.update(index, m.time_s, m.power_w);
    }

    fn recommend(&self) -> usize {
        self.policy.most_selected()
    }

    fn best_objective(&self) -> f64 {
        // Bandit recommendations are by pull count (Eq. 4), not by a
        // scalarized search objective; report the pull share instead.
        let total = self.policy.total_pulls().max(1.0);
        self.policy.counts()[self.policy.most_selected()] / total
    }

    fn counts(&self) -> Option<&[f64]> {
        Some(self.policy.counts())
    }

    fn name(&self) -> &'static str {
        self.policy.name()
    }
}

/// Declarative strategy selector — one grid axis of a
/// [`super::ScenarioGrid`]. Parsed from scenario files
/// (`strategies = "lasp,swucb:600,random"`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StrategySpec {
    /// The paper's tuner: UCB1, or candidate-subset LASP on large spaces
    /// (the [`lasp_policy`] budget rule).
    Lasp,
    /// Plain UCB1 regardless of space size.
    Ucb,
    /// ε-greedy with the given exploration rate.
    Epsilon(f64),
    /// Thompson sampling.
    Thompson,
    /// Sliding-window UCB; window 0 means `max(iterations, k)` (the
    /// effectively-unwindowed ablation setting).
    SwUcb(usize),
    /// Candidate-subset LASP with an explicit subset size; 0 means the
    /// recommended size for the budget.
    Subset(usize),
    /// Uniform random search.
    Random,
    /// Simulated annealing.
    Annealing,
    /// BLISS-style GP Bayesian optimization.
    Bliss,
    /// Hyperband-style successive halving over the fidelity knob.
    Halving,
    /// Replay a recorded flight-recorder capture (`sim.trace`) as the
    /// decision-and-reward stream — see [`super::replay`].
    Replay,
}

/// A constructed strategy: either a bandit policy or a search baseline.
/// [`Built::step`] exposes both through the one [`SearchStep`] interface.
pub enum Built {
    Policy(Box<dyn Policy>),
    Search(Box<dyn Searcher>),
}

impl Built {
    /// Begin the incremental run (borrows the built strategy).
    pub fn step<'a>(&'a mut self, k: usize, budget: usize, q: f64) -> Box<dyn SearchStep + 'a> {
        match self {
            Built::Policy(p) => Box::new(PolicyStep::new(p.as_mut())),
            Built::Search(s) => s.begin(k, budget, q),
        }
    }
}

impl StrategySpec {
    /// Parse one spec: a name with an optional `:arg` parameter
    /// (`epsilon:0.1`, `swucb:600`, `subset:64`).
    pub fn parse(s: &str) -> Result<StrategySpec> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n.trim(), Some(a.trim())),
            None => (s.trim(), None),
        };
        let num = |what: &str| -> Result<f64> {
            arg.ok_or_else(|| anyhow!("strategy '{name}' needs :{what}"))?
                .parse::<f64>()
                .map_err(|_| anyhow!("strategy '{name}': bad {what} '{}'", arg.unwrap_or("")))
        };
        // Validate at parse time (like every other scenario-file field), so
        // a bad arg is a CLI error, not a panic inside a pool worker.
        let count = |what: &str| -> Result<usize> {
            let v = num(what)?;
            if !(v.is_finite() && v > 0.0 && v.fract() == 0.0 && v <= 1e9) {
                return Err(anyhow!("strategy '{name}': {what} must be a positive integer"));
            }
            Ok(v as usize)
        };
        Ok(match name {
            "lasp" => StrategySpec::Lasp,
            "ucb" => StrategySpec::Ucb,
            "epsilon" => {
                let rate = if arg.is_some() { num("rate")? } else { 0.1 };
                if !(0.0..=1.0).contains(&rate) {
                    return Err(anyhow!("strategy 'epsilon': rate must lie in [0, 1]"));
                }
                StrategySpec::Epsilon(rate)
            }
            "thompson" => StrategySpec::Thompson,
            "swucb" => StrategySpec::SwUcb(if arg.is_some() { count("window")? } else { 0 }),
            "subset" => StrategySpec::Subset(if arg.is_some() { count("size")? } else { 0 }),
            "random" => StrategySpec::Random,
            "annealing" => StrategySpec::Annealing,
            "bliss" => StrategySpec::Bliss,
            "halving" => StrategySpec::Halving,
            "replay" => StrategySpec::Replay,
            other => {
                return Err(anyhow!(
                    "unknown strategy '{other}' \
                     (lasp|ucb|epsilon[:rate]|thompson|swucb[:window]|subset[:size]|\
                     random|annealing|bliss|halving|replay)"
                ))
            }
        })
    }

    /// Stable label for reports and JSON output.
    pub fn label(&self) -> String {
        match self {
            StrategySpec::Lasp => "lasp".into(),
            StrategySpec::Ucb => "ucb".into(),
            StrategySpec::Epsilon(e) => format!("epsilon:{e}"),
            StrategySpec::Thompson => "thompson".into(),
            StrategySpec::SwUcb(0) => "swucb".into(),
            StrategySpec::SwUcb(w) => format!("swucb:{w}"),
            StrategySpec::Subset(0) => "subset".into(),
            StrategySpec::Subset(m) => format!("subset:{m}"),
            StrategySpec::Random => "random".into(),
            StrategySpec::Annealing => "annealing".into(),
            StrategySpec::Bliss => "bliss".into(),
            StrategySpec::Halving => "halving".into(),
            StrategySpec::Replay => "replay".into(),
        }
    }

    /// Construct the strategy for a `k`-arm space under an `iterations`
    /// budget, seeded deterministically from the scenario seed.
    pub fn build(
        &self,
        k: usize,
        iterations: usize,
        alpha: f64,
        beta: f64,
        seed: u64,
    ) -> Built {
        match *self {
            StrategySpec::Lasp => Built::Policy(lasp_policy(k, iterations, alpha, beta, seed)),
            StrategySpec::Ucb => Built::Policy(Box::new(UcbTuner::new(k, alpha, beta))),
            StrategySpec::Epsilon(eps) => {
                Built::Policy(Box::new(EpsilonGreedy::new(k, alpha, beta, eps, seed)))
            }
            StrategySpec::Thompson => {
                Built::Policy(Box::new(ThompsonSampler::new(k, alpha, beta, seed)))
            }
            StrategySpec::SwUcb(window) => {
                // A window below the arm count cannot even cover the init
                // sweep (and SlidingWindowUcb rejects it): clamp up, so one
                // grid line like `swucb:400` works across apps from
                // Clomp (125 arms) to Hypre (92,160).
                let w = if window == 0 { iterations.max(k) } else { window.max(k) };
                Built::Policy(Box::new(SlidingWindowUcb::new(k, alpha, beta, w)))
            }
            StrategySpec::Subset(m) => {
                let m = if m == 0 { SubsetTuner::recommended_size(k, iterations) } else { m };
                // Same seed decorrelation as `lasp_policy`: the candidate
                // sampler must not share the device RNG's starting state.
                Built::Policy(Box::new(SubsetTuner::new(k, m.min(k), alpha, beta, seed ^ 0xA5A5)))
            }
            StrategySpec::Random => Built::Search(Box::new(RandomSearch::new(seed, alpha, beta))),
            StrategySpec::Annealing => {
                Built::Search(Box::new(SimulatedAnnealing::new(seed, alpha, beta)))
            }
            StrategySpec::Bliss => Built::Search(Box::new(BlissBo::new(seed, alpha, beta))),
            StrategySpec::Halving => {
                Built::Search(Box::new(SuccessiveHalving::new(seed, alpha, beta)))
            }
            // Replay needs the scenario's trace file, which only the sweep
            // runner holds; `run_scenario` constructs a `ReplayStep`
            // directly and never reaches this arm.
            StrategySpec::Replay => unreachable!(
                "replay strategies are built by run_scenario from sim.trace"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_labels() {
        for s in [
            "lasp", "ucb", "thompson", "swucb", "swucb:600", "subset:64", "random", "annealing",
            "bliss", "halving", "replay",
        ] {
            let spec = StrategySpec::parse(s).unwrap();
            assert_eq!(spec.label(), s, "label drifted for {s}");
        }
        assert_eq!(StrategySpec::parse("epsilon:0.2").unwrap(), StrategySpec::Epsilon(0.2));
        assert_eq!(StrategySpec::parse("epsilon").unwrap(), StrategySpec::Epsilon(0.1));
        assert!(StrategySpec::parse("gradient-descent").is_err());
        assert!(StrategySpec::parse("epsilon:x").is_err());
        // Out-of-range args are parse errors, not mid-sweep panics.
        assert!(StrategySpec::parse("epsilon:1.5").is_err());
        assert!(StrategySpec::parse("swucb:-600").is_err());
        assert!(StrategySpec::parse("swucb:0").is_err());
        assert!(StrategySpec::parse("subset:2.5").is_err());
    }

    #[test]
    fn small_swucb_window_clamps_to_arm_count() {
        // One `swucb:400` grid line must work from Clomp to Hypre — the
        // window clamps up to k instead of tripping SlidingWindowUcb's
        // window >= k assertion inside a pool worker.
        let mut built = StrategySpec::SwUcb(400).build(92_160, 100, 0.8, 0.2, 1);
        let mut step = built.step(92_160, 100, 0.15);
        let d = step.next().unwrap().unwrap();
        assert!(d.index < 92_160);
    }

    #[test]
    fn every_spec_builds_and_steps() {
        for spec in [
            StrategySpec::Lasp,
            StrategySpec::Ucb,
            StrategySpec::Epsilon(0.1),
            StrategySpec::Thompson,
            StrategySpec::SwUcb(0),
            StrategySpec::Subset(8),
            StrategySpec::Random,
            StrategySpec::Annealing,
            StrategySpec::Bliss,
            StrategySpec::Halving,
        ] {
            let mut built = spec.build(32, 60, 1.0, 0.0, 7);
            let mut step = built.step(32, 60, 0.15);
            for _ in 0..20 {
                let Some(d) = step.next().unwrap() else { break };
                assert!(d.index < 32, "{}: arm out of range", step.name());
                let q = d.fidelity.unwrap_or(0.15);
                let m = Measurement { time_s: 1.0 + (d.index % 5) as f64 * 0.1, power_w: 5.0 };
                step.observe(d.index, q, m);
            }
            assert!(step.recommend() < 32);
        }
    }

    #[test]
    fn policy_step_mirrors_policy() {
        let mut p = UcbTuner::new(4, 1.0, 0.0);
        let mut step = PolicyStep::new(&mut p);
        for _ in 0..12 {
            let d = step.next().unwrap().unwrap();
            step.observe(d.index, 0.15, Measurement { time_s: 1.0 + d.index as f64, power_w: 4.0 });
        }
        let rec = step.recommend();
        assert_eq!(rec, 0, "fastest arm wins");
        assert_eq!(step.counts().unwrap().iter().sum::<f64>(), 12.0);
        assert!(step.best_objective() > 0.0);
    }

    #[test]
    fn lasp_policy_switches_to_subset_on_large_spaces() {
        assert_eq!(lasp_policy(64, 500, 1.0, 0.0, 1).name(), "lasp-ucb1");
        assert_eq!(lasp_policy(92_160, 500, 1.0, 0.0, 1).name(), "lasp-ucb1-subset");
    }
}
