//! Parameter/configuration space machinery (paper §II-A, Table II).
//!
//! A [`ParamSpace`] is the cartesian product of named, discrete
//! [`ParamDef`]s. Every point in the product is a *configuration* — one
//! bandit arm — addressed by a dense mixed-radix index in `0..space.len()`.
//! The dense indexing is what lets the AOT artifacts treat the whole space
//! as flat `f32[K]` vectors.

mod param;

pub use param::{ParamDef, Value};


/// A full cartesian parameter space.
#[derive(Debug, Clone)]
pub struct ParamSpace {
    name: String,
    params: Vec<ParamDef>,
    /// Mixed-radix strides; `strides[i]` = product of sizes of params after i.
    strides: Vec<usize>,
    size: usize,
}

/// One concrete configuration: the decoded values plus its dense index.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub index: usize,
    pub values: Vec<Value>,
}

impl ParamSpace {
    /// Build a space from parameter definitions. Panics on an empty product.
    pub fn new(name: impl Into<String>, params: Vec<ParamDef>) -> Self {
        assert!(!params.is_empty(), "empty parameter list");
        let mut size = 1usize;
        for p in &params {
            assert!(p.cardinality() > 0, "parameter {} has no values", p.name());
            size = size
                .checked_mul(p.cardinality())
                .expect("parameter space overflow");
        }
        let mut strides = vec![1usize; params.len()];
        for i in (0..params.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * params[i + 1].cardinality();
        }
        ParamSpace { name: name.into(), params, strides, size }
    }

    /// Space name (application name).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Total number of configurations (arms), i.e. `a_1 a_2 ... a_n`.
    pub fn len(&self) -> usize {
        self.size
    }

    /// True when the space has exactly one configuration.
    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    /// Number of tunable parameters (dimensions).
    pub fn dims(&self) -> usize {
        self.params.len()
    }

    /// Parameter definitions in declaration order.
    pub fn params(&self) -> &[ParamDef] {
        &self.params
    }

    /// Decode a dense index into per-parameter value positions.
    pub fn positions(&self, index: usize) -> Vec<usize> {
        assert!(index < self.size, "index {index} out of space {}", self.size);
        self.params
            .iter()
            .zip(&self.strides)
            .map(|(p, s)| (index / s) % p.cardinality())
            .collect()
    }

    /// Position of `index` along one dimension — the allocation-free
    /// single-axis decode the app models' hot `workload()` path uses
    /// (episode steps must not allocate; see `benches/sim_engine.rs`).
    pub fn dim_position(&self, index: usize, dim: usize) -> usize {
        (index / self.strides[dim]) % self.params[dim].cardinality()
    }

    /// Borrowed value of `index` along one dimension (allocation-free).
    pub fn value_at(&self, index: usize, dim: usize) -> &Value {
        &self.params[dim].values()[self.dim_position(index, dim)]
    }

    /// Decode a dense index into a [`Config`].
    pub fn decode(&self, index: usize) -> Config {
        let values = self
            .positions(index)
            .iter()
            .zip(&self.params)
            .map(|(&pos, p)| p.values()[pos].clone())
            .collect();
        Config { index, values }
    }

    /// Encode per-parameter value positions back to the dense index.
    pub fn encode_positions(&self, positions: &[usize]) -> usize {
        assert_eq!(positions.len(), self.params.len());
        positions
            .iter()
            .zip(&self.params)
            .zip(&self.strides)
            .map(|((&pos, p), s)| {
                assert!(pos < p.cardinality());
                pos * s
            })
            .sum()
    }

    /// Find a configuration index by named values; `None` if any value is
    /// absent from its parameter's domain.
    pub fn encode_named(&self, named: &[(&str, Value)]) -> Option<usize> {
        let mut positions = self.default_positions();
        for (name, value) in named {
            let (i, p) = self
                .params
                .iter()
                .enumerate()
                .find(|(_, p)| p.name() == *name)?;
            positions[i] = p.position_of(value)?;
        }
        Some(self.encode_positions(&positions))
    }

    /// Positions of every parameter's declared default value.
    pub fn default_positions(&self) -> Vec<usize> {
        self.params.iter().map(|p| p.default_position()).collect()
    }

    /// Dense index of the all-defaults configuration (Table II "Default").
    pub fn default_index(&self) -> usize {
        self.encode_positions(&self.default_positions())
    }

    /// Normalized feature vector in `[0, 1]^dims` for surrogate models
    /// (BLISS GP): each parameter mapped by its position within its domain.
    pub fn features(&self, index: usize) -> Vec<f64> {
        self.positions(index)
            .iter()
            .zip(&self.params)
            .map(|(&pos, p)| {
                if p.cardinality() == 1 {
                    0.5
                } else {
                    pos as f64 / (p.cardinality() - 1) as f64
                }
            })
            .collect()
    }

    /// Iterate over all dense indices.
    pub fn indices(&self) -> impl Iterator<Item = usize> {
        0..self.size
    }

    /// Human-readable rendering of a configuration.
    pub fn describe(&self, index: usize) -> String {
        let mut out = String::new();
        self.describe_into(index, &mut out);
        out
    }

    /// As [`Self::describe`], but appending into a caller-owned buffer —
    /// the serve hot path reuses one scratch string per worker instead
    /// of allocating a description per request.
    pub fn describe_into(&self, index: usize, out: &mut String) {
        use std::fmt::Write as _;
        let cfg = self.decode(index);
        let _ = write!(out, "#{index} {{");
        for (i, (p, v)) in self.params.iter().zip(&cfg.values).enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "{}={}", p.name(), v);
        }
        out.push('}');
    }
}

impl std::fmt::Display for Config {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "#{}[", self.index)?;
        for (i, v) in self.values.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ParamSpace {
        ParamSpace::new(
            "toy",
            vec![
                ParamDef::ints("a", &[1, 2, 3], 2),
                ParamDef::tags("b", &["x", "y"], "x"),
                ParamDef::floats("c", &[0.1, 0.2, 0.3, 0.4], 0.2),
            ],
        )
    }

    #[test]
    fn size_is_product() {
        assert_eq!(toy().len(), 3 * 2 * 4);
    }

    #[test]
    fn encode_decode_roundtrip_all() {
        let s = toy();
        for i in s.indices() {
            let pos = s.positions(i);
            assert_eq!(s.encode_positions(&pos), i);
            let cfg = s.decode(i);
            assert_eq!(cfg.index, i);
            assert_eq!(cfg.values.len(), 3);
        }
    }

    #[test]
    fn dim_decode_agrees_with_full_decode() {
        let s = toy();
        for i in s.indices() {
            let pos = s.positions(i);
            let cfg = s.decode(i);
            for dim in 0..s.dims() {
                assert_eq!(s.dim_position(i, dim), pos[dim]);
                assert_eq!(*s.value_at(i, dim), cfg.values[dim]);
            }
        }
    }

    #[test]
    fn default_index_matches_declared_defaults() {
        let s = toy();
        let d = s.decode(s.default_index());
        assert_eq!(d.values[0], Value::Int(2));
        assert_eq!(d.values[1], Value::Tag("x".into()));
        assert_eq!(d.values[2], Value::Float(0.2));
    }

    #[test]
    fn encode_named_finds_config() {
        let s = toy();
        let idx = s
            .encode_named(&[("a", Value::Int(3)), ("b", Value::Tag("y".into()))])
            .unwrap();
        let cfg = s.decode(idx);
        assert_eq!(cfg.values[0], Value::Int(3));
        assert_eq!(cfg.values[1], Value::Tag("y".into()));
        // Unspecified parameter keeps its default.
        assert_eq!(cfg.values[2], Value::Float(0.2));
        assert!(s.encode_named(&[("a", Value::Int(99))]).is_none());
        assert!(s.encode_named(&[("zzz", Value::Int(1))]).is_none());
    }

    #[test]
    fn features_normalized() {
        let s = toy();
        for i in s.indices() {
            for f in s.features(i) {
                assert!((0.0..=1.0).contains(&f));
            }
        }
        // First config: all positions 0 -> features all 0.
        assert_eq!(s.features(0), vec![0.0, 0.0, 0.0]);
        // Last config: all positions max -> features all 1.
        assert_eq!(s.features(s.len() - 1), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_index_panics() {
        toy().positions(24);
    }

    #[test]
    fn describe_contains_names() {
        let d = toy().describe(0);
        assert!(d.contains("a=1") && d.contains("b=x") && d.contains("c=0.1"));
    }
}
