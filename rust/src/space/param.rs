//! Single tunable parameter definitions.


/// A parameter value: integer, float, or categorical tag (e.g. Kripke's
/// `Layout` ∈ {DGZ, DZG, GDZ, GZD, ZDG, ZGD}).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Tag(String),
}

impl Value {
    /// Integer payload; panics if the value is not an `Int`.
    pub fn as_int(&self) -> i64 {
        match self {
            Value::Int(v) => *v,
            other => panic!("expected Int, got {other:?}"),
        }
    }

    /// Float payload (ints coerce); panics on tags.
    pub fn as_float(&self) -> f64 {
        match self {
            Value::Float(v) => *v,
            Value::Int(v) => *v as f64,
            other => panic!("expected numeric, got {other:?}"),
        }
    }

    /// Tag payload; panics otherwise.
    pub fn as_tag(&self) -> &str {
        match self {
            Value::Tag(s) => s,
            other => panic!("expected Tag, got {other:?}"),
        }
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Tag(s) => write!(f, "{s}"),
        }
    }
}

/// A named tunable parameter with a finite ordered domain and a default.
#[derive(Debug, Clone)]
pub struct ParamDef {
    name: String,
    values: Vec<Value>,
    default_pos: usize,
    /// One-line description (Table II "Parameter Description").
    description: String,
}

impl ParamDef {
    /// Generic constructor; `default_pos` indexes into `values`.
    pub fn new(
        name: impl Into<String>,
        values: Vec<Value>,
        default_pos: usize,
        description: impl Into<String>,
    ) -> Self {
        assert!(!values.is_empty());
        assert!(default_pos < values.len());
        ParamDef {
            name: name.into(),
            values,
            default_pos,
            description: description.into(),
        }
    }

    /// Integer-valued parameter; `default` must be one of `vals`.
    pub fn ints(name: impl Into<String>, vals: &[i64], default: i64) -> Self {
        let values: Vec<Value> = vals.iter().map(|&v| Value::Int(v)).collect();
        let pos = vals
            .iter()
            .position(|&v| v == default)
            .expect("default not in domain");
        ParamDef::new(name, values, pos, "")
    }

    /// Contiguous integer range `lo..=hi`.
    pub fn int_range(name: impl Into<String>, lo: i64, hi: i64, default: i64) -> Self {
        let vals: Vec<i64> = (lo..=hi).collect();
        ParamDef::ints(name, &vals, default)
    }

    /// Float-valued parameter.
    pub fn floats(name: impl Into<String>, vals: &[f64], default: f64) -> Self {
        let values: Vec<Value> = vals.iter().map(|&v| Value::Float(v)).collect();
        let pos = vals
            .iter()
            .position(|&v| v == default)
            .expect("default not in domain");
        ParamDef::new(name, values, pos, "")
    }

    /// Categorical parameter.
    pub fn tags(name: impl Into<String>, vals: &[&str], default: &str) -> Self {
        let values: Vec<Value> = vals.iter().map(|v| Value::Tag(v.to_string())).collect();
        let pos = vals
            .iter()
            .position(|v| *v == default)
            .expect("default not in domain");
        ParamDef::new(name, values, pos, "")
    }

    /// Attach a human-readable description (builder style).
    pub fn describe(mut self, d: impl Into<String>) -> Self {
        self.description = d.into();
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn description(&self) -> &str {
        &self.description
    }

    pub fn values(&self) -> &[Value] {
        &self.values
    }

    pub fn cardinality(&self) -> usize {
        self.values.len()
    }

    pub fn default_position(&self) -> usize {
        self.default_pos
    }

    pub fn default_value(&self) -> &Value {
        &self.values[self.default_pos]
    }

    /// Position of `value` in the domain, if present.
    pub fn position_of(&self, value: &Value) -> Option<usize> {
        self.values.iter().position(|v| v == value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_range_inclusive() {
        let p = ParamDef::int_range("r", 1, 15, 11);
        assert_eq!(p.cardinality(), 15);
        assert_eq!(p.default_value(), &Value::Int(11));
    }

    #[test]
    fn tags_default_position() {
        let p = ParamDef::tags("layout", &["DGZ", "DZG", "GDZ"], "DGZ");
        assert_eq!(p.default_position(), 0);
        assert_eq!(p.position_of(&Value::Tag("GDZ".into())), Some(2));
        assert_eq!(p.position_of(&Value::Tag("nope".into())), None);
    }

    #[test]
    #[should_panic]
    fn default_must_be_in_domain() {
        ParamDef::ints("x", &[1, 2], 3);
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Int(3).as_int(), 3);
        assert_eq!(Value::Int(3).as_float(), 3.0);
        assert_eq!(Value::Float(0.5).as_float(), 0.5);
        assert_eq!(Value::Tag("a".into()).as_tag(), "a");
    }

    #[test]
    fn display_forms() {
        assert_eq!(Value::Int(7).to_string(), "7");
        assert_eq!(Value::Tag("ZDG".into()).to_string(), "ZDG");
    }
}
