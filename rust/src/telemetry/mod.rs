//! Tuner resource telemetry (paper Fig 10: CPU and memory footprint of the
//! autotuner itself, LASP vs BLISS, on MAXN vs 5W).
//!
//! Two sources:
//! * **real process sampling** — RSS and CPU time of *this* process read
//!   from `/proc/self`, sampled while a tuner runs (what our Fig 10 bench
//!   reports for our own implementations);
//! * **footprint model** — an analytic estimate of what each tuner would
//!   occupy on the Jetson (scaled by the mode's clock), used to put LASP
//!   and BLISS on the paper's axes.


/// Aggregated resource usage over a tuning session.
#[derive(Debug, Clone, Default)]
pub struct ResourceReport {
    /// Peak resident set size delta over the session, MiB.
    pub peak_rss_mib: f64,
    /// Mean RSS over samples, MiB.
    pub mean_rss_mib: f64,
    /// CPU seconds consumed by this process during the session.
    pub cpu_seconds: f64,
    /// Wall seconds elapsed.
    pub wall_seconds: f64,
    /// Samples taken.
    pub samples: usize,
}

impl ResourceReport {
    /// Average CPU utilization of one core, percent.
    pub fn cpu_pct(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            100.0 * self.cpu_seconds / self.wall_seconds
        }
    }

    /// Append Prometheus-style gauges for this report under `prefix`
    /// (used by the serve layer's `GET /metrics`).
    pub fn render_prometheus(&self, prefix: &str, out: &mut String) {
        use std::fmt::Write as _;
        let mut gauge = |name: &str, v: f64| {
            let _ = writeln!(out, "# TYPE {prefix}_{name} gauge\n{prefix}_{name} {v}");
        };
        gauge("peak_rss_mib", self.peak_rss_mib);
        gauge("mean_rss_mib", self.mean_rss_mib);
        gauge("cpu_seconds", self.cpu_seconds);
        gauge("wall_seconds", self.wall_seconds);
        gauge("cpu_pct", self.cpu_pct());
    }
}

/// Samples `/proc/self` while a tuner runs.
pub struct ResourceTracker {
    start_cpu: f64,
    start_wall: std::time::Instant,
    baseline_rss: f64,
    peak_rss: f64,
    rss_sum: f64,
    samples: usize,
}

/// Read (rss_mib, cpu_seconds) for the current process. Falls back to zeros
/// off-Linux.
pub fn read_self_usage() -> (f64, f64) {
    let statm = std::fs::read_to_string("/proc/self/statm").unwrap_or_default();
    let rss_pages: f64 = statm
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.0);
    let page_kib = 4.0; // x86-64/aarch64 default
    let rss_mib = rss_pages * page_kib / 1024.0;

    let stat = std::fs::read_to_string("/proc/self/stat").unwrap_or_default();
    // utime+stime are fields 14/15 (1-based) after the comm field, which can
    // contain spaces — split after the closing paren.
    let cpu = stat
        .rsplit_once(')')
        .map(|(_, rest)| {
            let f: Vec<&str> = rest.split_whitespace().collect();
            let utime: f64 = f.get(11).and_then(|s| s.parse().ok()).unwrap_or(0.0);
            let stime: f64 = f.get(12).and_then(|s| s.parse().ok()).unwrap_or(0.0);
            (utime + stime) / clock_ticks_per_sec()
        })
        .unwrap_or(0.0);
    (rss_mib, cpu)
}

fn clock_ticks_per_sec() -> f64 {
    // sysconf(_SC_CLK_TCK) is 100 on every Linux we target.
    100.0
}

impl ResourceTracker {
    /// Begin tracking now.
    pub fn start() -> Self {
        let (rss, cpu) = read_self_usage();
        ResourceTracker {
            start_cpu: cpu,
            start_wall: std::time::Instant::now(),
            baseline_rss: rss,
            peak_rss: rss,
            rss_sum: 0.0,
            samples: 0,
        }
    }

    /// Take one sample (cheap; call per iteration or per batch).
    pub fn sample(&mut self) {
        // Sampling /proc every iteration is itself overhead; subsample.
        if self.samples % 16 == 0 {
            let (rss, _) = read_self_usage();
            self.peak_rss = self.peak_rss.max(rss);
            self.rss_sum += rss;
        }
        self.samples += 1;
    }

    /// Finish and summarize.
    pub fn report(&self) -> ResourceReport {
        let (rss, cpu) = read_self_usage();
        let peak = self.peak_rss.max(rss);
        let taken = (self.samples / 16).max(1);
        ResourceReport {
            peak_rss_mib: (peak - self.baseline_rss).max(0.0) + 0.0,
            mean_rss_mib: if self.samples == 0 {
                rss
            } else {
                self.rss_sum / taken as f64
            },
            cpu_seconds: (cpu - self.start_cpu).max(0.0),
            wall_seconds: self.start_wall.elapsed().as_secs_f64(),
            samples: self.samples,
        }
    }
}

/// Analytic footprint model for Fig 10's four bars: what each tuner costs
/// *on the Jetson*, derived from its per-iteration work.
///
/// * LASP: one O(K) vector pass per iteration + O(K) f64 state.
/// * BLISS (BO/GP): O(N²·D) kernel build + O(N³) Cholesky per iteration on
///   a growing observation set, plus surrogate-pool state — the published
///   BLISS keeps several models.
#[derive(Debug, Clone, Copy)]
pub struct FootprintModel {
    /// Arm count of the tuned application.
    pub arms: usize,
    /// Observations the surrogate retains (BLISS) — 0 for LASP.
    pub surrogate_obs: usize,
    /// Surrogate pool size (BLISS trains several lightweight models).
    pub surrogate_pool: usize,
}

/// Estimated (cpu_pct, rss_mib) on a Jetson power mode.
pub fn jetson_footprint(
    m: &FootprintModel,
    mode: crate::device::PowerMode,
) -> (f64, f64) {
    let spec = mode.spec();
    // Normalize work against the MAXN clock: the same tuner burns a larger
    // share of a slower core (the paper's 5W bars are higher).
    let clock_ratio = 1.479 / spec.freq_ghz;
    if m.surrogate_obs == 0 {
        // LASP: 3 f64 vectors of length K streamed once per iteration.
        let vec_pass_ms = (m.arms as f64) * 3.0 * 8.0 / 2.0e9 * 1e3 * clock_ratio;
        // Assume ~1 iteration per second of app runtime: cpu% ≈ pass/1s.
        let cpu_pct = (vec_pass_ms / 10.0 + 1.2) * clock_ratio;
        let rss_mib = 4.0 + (m.arms as f64) * 3.0 * 8.0 / 1.0e6;
        (cpu_pct, rss_mib)
    } else {
        let n = m.surrogate_obs as f64;
        let pool = m.surrogate_pool.max(1) as f64;
        // GP iteration: kernel build + Cholesky, per surrogate in the pool.
        let flops = pool * (n * n * 12.0 + n * n * n / 3.0);
        let cpu_pct = (flops / 2.0e7 + 8.0) * clock_ratio;
        // Python + sklearn-ish resident footprint plus pool state.
        let rss_mib = 120.0 + pool * n * n * 8.0 / 1.0e6 + (m.arms as f64) * 1.6e-4;
        (cpu_pct.min(100.0 * spec.cores as f64), rss_mib)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::PowerMode;

    #[test]
    fn read_usage_nonzero_on_linux() {
        let (rss, _cpu) = read_self_usage();
        assert!(rss > 1.0, "rss {rss}");
    }

    #[test]
    fn tracker_reports_consistent() {
        let mut t = ResourceTracker::start();
        let mut v = vec![0u8; 4 << 20];
        for (i, b) in v.iter_mut().enumerate() {
            *b = i as u8;
        }
        for _ in 0..64 {
            t.sample();
        }
        let r = t.report();
        assert_eq!(r.samples, 64);
        assert!(r.wall_seconds >= 0.0);
        assert!(r.mean_rss_mib > 0.0);
        drop(v);
    }

    #[test]
    fn lasp_footprint_below_bliss() {
        // Fig 10's headline: LASP uses far less CPU and memory than BLISS.
        for mode in [PowerMode::Maxn, PowerMode::FiveW] {
            let lasp = jetson_footprint(
                &FootprintModel { arms: 92_160, surrogate_obs: 0, surrogate_pool: 0 },
                mode,
            );
            let bliss = jetson_footprint(
                &FootprintModel { arms: 92_160, surrogate_obs: 64, surrogate_pool: 4 },
                mode,
            );
            assert!(lasp.0 < bliss.0, "{mode:?} cpu {} !< {}", lasp.0, bliss.0);
            assert!(lasp.1 < bliss.1, "{mode:?} rss {} !< {}", lasp.1, bliss.1);
        }
    }

    #[test]
    fn five_watt_mode_costs_more_cpu_share() {
        let m = FootprintModel { arms: 216, surrogate_obs: 0, surrogate_pool: 0 };
        let maxn = jetson_footprint(&m, PowerMode::Maxn);
        let five = jetson_footprint(&m, PowerMode::FiveW);
        assert!(five.0 > maxn.0);
    }

    #[test]
    fn cpu_pct_zero_without_time() {
        let r = ResourceReport::default();
        assert_eq!(r.cpu_pct(), 0.0);
    }

    #[test]
    fn prometheus_render_emits_all_gauges() {
        let r = ResourceReport {
            peak_rss_mib: 3.5,
            mean_rss_mib: 2.0,
            cpu_seconds: 1.25,
            wall_seconds: 2.5,
            samples: 10,
        };
        let mut out = String::new();
        r.render_prometheus("proc", &mut out);
        assert!(out.contains("proc_peak_rss_mib 3.5"), "{out}");
        assert!(out.contains("proc_cpu_pct 50"), "{out}");
        assert!(out.contains("# TYPE proc_wall_seconds gauge"), "{out}");
    }
}
