//! The single-device tuning session: glue between an application model, a
//! device simulator, and a bandit policy (paper Fig 5's block diagram).
//! Since the scenario-engine refactor the actual loop lives in
//! [`crate::sim::Episode`]; a session is a thin owning wrapper that
//! assembles an episode from its parts.

use crate::apps::AppModel;
use crate::bandit::{Policy, UcbTuner};
use crate::device::{Device, Measurement};
use crate::sim::{Episode, EpisodeSpec, PolicyStep};
use crate::util::stats;
use anyhow::Result;

/// Session parameters (paper Alg. 1 inputs).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Total iterations `T`.
    pub iterations: usize,
    /// Execution-time weight α.
    pub alpha: f64,
    /// Power weight β.
    pub beta: f64,
    /// Record the full per-iteration history (arm, measurement).
    pub record_history: bool,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { iterations: 500, alpha: 0.8, beta: 0.2, record_history: true }
    }
}

/// Result of a tuning session.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Eq. 4: most frequently selected arm — the tuned configuration.
    pub best_index: usize,
    /// Human-readable rendering of the tuned configuration.
    pub best_config: String,
    /// Pull counts per arm at the end.
    pub counts: Vec<f64>,
    /// Per-iteration (arm, measurement) if recording was enabled.
    pub history: Vec<(usize, Measurement)>,
    /// Cumulative-regret trajectory if a regret oracle was installed.
    pub regret: Option<Vec<f64>>,
    /// Tuner resource footprint over the session.
    pub resources: crate::telemetry::ResourceReport,
    /// Total simulated seconds of application execution ("device time").
    pub simulated_device_seconds: f64,
    /// Wall-clock seconds the tuner itself spent (the lightweight claim).
    pub tuner_wall_seconds: f64,
}

/// One tuning run of a policy against an app on a device.
pub struct TuningSession {
    app: Box<dyn AppModel>,
    device: Box<dyn Device>,
    policy: Box<dyn Policy>,
    config: SessionConfig,
    regret_mu: Option<Vec<f64>>,
}

impl TuningSession {
    /// LASP session: UCB1 policy with the scalar backend.
    pub fn new(app: Box<dyn AppModel>, device: Box<dyn Device>, config: SessionConfig) -> Self {
        let k = app.space().len();
        let policy = Box::new(UcbTuner::new(k, config.alpha, config.beta));
        Self::with_policy(app, device, policy, config)
    }

    /// Session with an explicit policy (ablations, PJRT backend, …).
    pub fn with_policy(
        app: Box<dyn AppModel>,
        device: Box<dyn Device>,
        policy: Box<dyn Policy>,
        config: SessionConfig,
    ) -> Self {
        assert_eq!(policy.k(), app.space().len(), "policy/space arm mismatch");
        TuningSession { app, device, policy, config, regret_mu: None }
    }

    /// Install a regret oracle (per-arm expected rewards) for Fig 11.
    pub fn with_regret_oracle(mut self, mu: Vec<f64>) -> Self {
        assert_eq!(mu.len(), self.app.space().len());
        self.regret_mu = Some(mu);
        self
    }

    /// Run `config.iterations` rounds through one [`crate::sim::Episode`].
    pub fn run(&mut self) -> Result<Outcome> {
        let spec = EpisodeSpec {
            iterations: self.config.iterations,
            record_trace: false,
            record_history: self.config.record_history,
            track_resources: true,
            regret_mu: self.regret_mu.clone(),
            chaos_seed: 0,
        };
        let out = {
            let mut step = PolicyStep::new(self.policy.as_mut());
            Episode::new(self.app.as_ref(), self.device.as_mut(), &mut step, &[], &spec).run()?
        };
        let best_index = self.policy.most_selected();
        Ok(Outcome {
            best_index,
            best_config: self.app.space().describe(best_index),
            counts: self.policy.counts().to_vec(),
            history: out.history.unwrap_or_default(),
            regret: out.regret,
            resources: out.resources.unwrap_or_default(),
            simulated_device_seconds: out.simulated_device_seconds,
            tuner_wall_seconds: out.tuner_wall_seconds,
        })
    }

    /// The app under tuning.
    pub fn app(&self) -> &dyn AppModel {
        self.app.as_ref()
    }

    /// Checkpoint the policy's arm-statistics core. Since the unified-core
    /// refactor every policy exposes one, so any session is persistable.
    pub fn save_policy_state(
        &self,
        path: &std::path::Path,
        app: &str,
        alpha: f64,
        beta: f64,
    ) -> Result<()> {
        crate::bandit::persist::save(path, self.policy.stats(), app, alpha, beta)
    }
}

/// Exhaustively evaluate the *expected* (noise-free) behaviour of every arm
/// of `app` at fidelity `q` on a device spec, returning per-arm
/// (time, power). This is the Oracle sweep used by Fig 2/3/4/9/11.
pub fn oracle_sweep(
    app: &dyn AppModel,
    spec: &crate::device::DeviceSpec,
    q: f64,
) -> Vec<Measurement> {
    app.space()
        .indices()
        .map(|i| crate::device::run_with_cap(spec, &app.workload(i, q)))
        .collect()
}

/// Per-arm expected Eq. 5 rewards from an oracle sweep (regret oracle).
pub fn expected_rewards(sweep: &[Measurement], alpha: f64, beta: f64) -> Vec<f64> {
    let tau: Vec<f64> = sweep.iter().map(|m| m.time_s).collect();
    let rho: Vec<f64> = sweep.iter().map(|m| m.power_w).collect();
    crate::bandit::reward::weighted_rewards(&tau, &rho, alpha, beta)
}

/// Distance-from-Oracle metric (paper §II-A):
/// `(time(x)/time(oracle) − 1) · 100%`.
pub fn oracle_distance_pct(sweep: &[Measurement], index: usize) -> f64 {
    let times: Vec<f64> = sweep.iter().map(|m| m.time_s).collect();
    let oracle = times[stats::argmin(&times)];
    (times[index] / oracle - 1.0) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{self, AppKind};
    use crate::device::{JetsonNano, PowerMode};

    fn session(iters: usize, alpha: f64, beta: f64) -> TuningSession {
        TuningSession::new(
            apps::build(AppKind::Clomp),
            Box::new(JetsonNano::new(PowerMode::Maxn, 42)),
            SessionConfig { iterations: iters, alpha, beta, record_history: true },
        )
    }

    #[test]
    fn runs_to_completion_with_history() {
        let mut s = session(300, 0.8, 0.2);
        let out = s.run().unwrap();
        assert_eq!(out.history.len(), 300);
        assert_eq!(out.counts.iter().sum::<f64>(), 300.0);
        assert!(out.simulated_device_seconds > 0.0);
        assert!(out.best_config.contains("partsPerThread"));
    }

    #[test]
    fn finds_configuration_better_than_default() {
        let app = apps::build(AppKind::Clomp);
        let spec = PowerMode::Maxn.spec();
        let sweep = oracle_sweep(app.as_ref(), &spec, 0.15);
        let default_time = sweep[app.default_index()].time_s;

        let mut s = session(600, 1.0, 0.0);
        let out = s.run().unwrap();
        let tuned_time = sweep[out.best_index].time_s;
        assert!(
            tuned_time < default_time,
            "tuned {tuned_time} !< default {default_time}"
        );
    }

    #[test]
    fn regret_trajectory_saturates() {
        let app = apps::build(AppKind::Clomp);
        let spec = PowerMode::Maxn.spec();
        let sweep = oracle_sweep(app.as_ref(), &spec, 0.15);
        let mu = expected_rewards(&sweep, 0.8, 0.2);
        let mut s = session(1000, 0.8, 0.2).with_regret_oracle(mu);
        let out = s.run().unwrap();
        let regret = out.regret.unwrap();
        assert_eq!(regret.len(), 1000);
        // Regret increments in the last quarter must be much smaller than
        // in the first quarter (log saturation, Fig 11).
        let first_q = regret[249];
        let last_q = regret[999] - regret[749];
        assert!(last_q < first_q, "first {first_q} last {last_q}");
    }

    #[test]
    fn oracle_distance_zero_for_oracle() {
        let app = apps::build(AppKind::Lulesh);
        let spec = PowerMode::Maxn.spec();
        let sweep = oracle_sweep(app.as_ref(), &spec, 1.0);
        let times: Vec<f64> = sweep.iter().map(|m| m.time_s).collect();
        let oracle = stats::argmin(&times);
        assert_eq!(oracle_distance_pct(&sweep, oracle), 0.0);
        assert!(oracle_distance_pct(&sweep, app.default_index()) > 0.0);
    }
}
