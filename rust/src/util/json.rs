//! Minimal JSON parser (no external crates are available in this offline
//! build — see Cargo.toml). Supports the full JSON grammar minus exotic
//! number forms; enough to read `artifacts/manifest.json` and to write the
//! experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"t":true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let m = r#"{"format": "hlo-text", "return_tuple": true,
          "artifacts": [{"name": "lasp_step_kripke", "file": "lasp_step_kripke.hlo.txt",
            "inputs": [{"shape": [216], "dtype": "f32"}], "outputs": [{"shape": [], "dtype": "s32"}],
            "kind": "lasp_step", "k": 216, "app": "kripke"}]}"#;
        let v = Json::parse(m).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("k").unwrap().as_usize(), Some(216));
    }
}
