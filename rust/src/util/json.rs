//! Minimal JSON support (no external crates are available in this offline
//! build — see Cargo.toml). Two layers:
//!
//! * [`Json`] — an owned tree parser/serializer supporting the full JSON
//!   grammar minus exotic number forms; enough to read
//!   `artifacts/manifest.json` and to write the experiment result files.
//! * [`JsonSlice`] / [`JsonWriter`] — the serve hot path's borrowed layer:
//!   a zero-copy reader over `&[u8]` (field access without building a
//!   tree; strings borrow from the input unless they contain escapes) and
//!   a writer that serializes straight into a caller-owned `Vec<u8>` so a
//!   reused buffer makes steady-state serialization allocation-free.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integral, in-range numbers only: negative, fractional, non-finite
    /// or `> 2^53` values return `None` instead of truncating (an `f64`
    /// cannot even represent exact integers beyond 2^53).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(f64_to_usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Shared strict `f64 -> usize` conversion (also used by [`JsonSlice`]).
fn f64_to_usize(f: f64) -> Option<usize> {
    const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    if f.is_finite() && f >= 0.0 && f.fract() == 0.0 && f <= MAX_EXACT {
        Some(f as usize)
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// Borrowed layer: JsonSlice (reader) + JsonWriter (serializer).
// ---------------------------------------------------------------------------

/// Nesting ceiling for the borrowed scanner (adversarial `[[[[…` input
/// must not overflow the stack of a server thread).
const MAX_DEPTH: usize = 64;

/// A borrowed JSON value: a validated byte span inside a caller-owned
/// buffer. Field access re-scans the (tiny) span instead of building a
/// tree, so reading a request body performs zero heap allocations unless
/// a string actually contains escape sequences.
#[derive(Debug, Clone, Copy)]
pub struct JsonSlice<'a> {
    /// Trimmed span of exactly one JSON value.
    bytes: &'a [u8],
}

impl<'a> JsonSlice<'a> {
    /// Validate `bytes` as one JSON document and wrap it. No tree is
    /// built; the scan only checks well-formedness (and bounds nesting
    /// depth), so later accessors can navigate without re-validating.
    pub fn parse(bytes: &'a [u8]) -> Result<JsonSlice<'a>, String> {
        let mut s = Scan { bytes, pos: 0 };
        s.skip_ws();
        let start = s.pos;
        s.skip_value(0)?;
        let end = s.pos;
        s.skip_ws();
        if s.pos != bytes.len() {
            return Err(format!("trailing garbage at byte {}", s.pos));
        }
        Ok(JsonSlice { bytes: &bytes[start..end] })
    }

    /// The raw (trimmed) span of this value.
    pub fn raw(&self) -> &'a [u8] {
        self.bytes
    }

    pub fn is_null(&self) -> bool {
        self.bytes == b"null"
    }

    pub fn is_obj(&self) -> bool {
        self.bytes.first() == Some(&b'{')
    }

    /// Object field lookup by linear scan. `O(len)` per call — request
    /// bodies are a few hundred bytes, so rescanning beats allocating a
    /// map. Returns `None` on non-objects and absent keys.
    pub fn get(&self, key: &str) -> Option<JsonSlice<'a>> {
        let mut s = Scan { bytes: self.bytes, pos: 0 };
        if s.peek() != Some(b'{') {
            return None;
        }
        s.pos += 1;
        s.skip_ws();
        if s.peek() == Some(b'}') {
            return None;
        }
        loop {
            s.skip_ws();
            let kspan = s.string_span().ok()?;
            s.skip_ws();
            if s.peek() != Some(b':') {
                return None;
            }
            s.pos += 1;
            s.skip_ws();
            let vstart = s.pos;
            s.skip_value(0).ok()?;
            let vend = s.pos;
            if string_content_eq(kspan, key) {
                return Some(JsonSlice { bytes: &self.bytes[vstart..vend] });
            }
            s.skip_ws();
            match s.peek() {
                Some(b',') => s.pos += 1,
                _ => return None, // '}' (key absent) or garbage
            }
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        let c = *self.bytes.first()?;
        if c != b'-' && !c.is_ascii_digit() {
            return None;
        }
        std::str::from_utf8(self.bytes).ok()?.parse().ok()
    }

    /// Strict integral conversion (see [`Json::as_usize`]).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(f64_to_usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self.bytes {
            b"true" => Some(true),
            b"false" => Some(false),
            _ => None,
        }
    }

    /// String value; borrows from the input unless the string contains
    /// escape sequences. Invalid UTF-8 or bad escapes return `None`.
    pub fn as_str(&self) -> Option<Cow<'a, str>> {
        if self.bytes.first() != Some(&b'"') || self.bytes.len() < 2 {
            return None;
        }
        unescape(&self.bytes[1..self.bytes.len() - 1])
    }

    pub fn is_arr(&self) -> bool {
        self.bytes.first() == Some(&b'[')
    }

    /// Iterate the elements of a JSON array as borrowed sub-slices. A
    /// non-array value yields an empty iterator (pair with [`Self::is_arr`]
    /// when absence and emptiness must be distinguished). Like
    /// [`Self::get`], this re-scans the already-validated span, so
    /// iteration allocates nothing.
    pub fn items(&self) -> JsonItems<'a> {
        let inside = self.bytes.first() == Some(&b'[');
        JsonItems {
            bytes: self.bytes,
            pos: if inside { 1 } else { 0 },
            inside,
        }
    }

    /// Iterate the fields of a JSON object as `(inner key span, value)`
    /// pairs in document order. The key span is the *undecoded* bytes
    /// between the quotes (compare with [`Self::get`]'s key handling);
    /// non-objects yield an empty iterator. Allocates nothing.
    pub fn fields(&self) -> JsonFields<'a> {
        let inside = self.bytes.first() == Some(&b'{');
        JsonFields {
            bytes: self.bytes,
            pos: if inside { 1 } else { 0 },
            inside,
        }
    }

    /// Whether this object repeats a key at its top level. Duplicate keys
    /// are grammatical JSON but ambiguous for a request codec — `get`
    /// returns the first occurrence while tree parsers keep the last — so
    /// the batch endpoints reject entries carrying them instead of
    /// guessing. Pairwise span compares over a handful of fields; decoding
    /// only happens when a key actually contains escapes. Non-objects
    /// report `false`.
    pub fn has_duplicate_keys(&self) -> bool {
        let mut i = 0usize;
        for (ka, _) in self.fields() {
            for (kb, _) in self.fields().take(i) {
                if json_key_eq(ka, kb) {
                    return true;
                }
            }
            i += 1;
        }
        false
    }
}

/// Compare two undecoded key spans for semantic equality (escape-aware;
/// the escape-free fast path is a plain byte compare).
fn json_key_eq(a: &[u8], b: &[u8]) -> bool {
    if !a.contains(&b'\\') && !b.contains(&b'\\') {
        return a == b;
    }
    match (unescape(a), unescape(b)) {
        (Some(x), Some(y)) => x == y,
        _ => a == b,
    }
}

/// Iterator over the elements of a [`JsonSlice`] array (see
/// [`JsonSlice::items`]).
pub struct JsonItems<'a> {
    bytes: &'a [u8],
    pos: usize,
    inside: bool,
}

impl<'a> Iterator for JsonItems<'a> {
    type Item = JsonSlice<'a>;

    fn next(&mut self) -> Option<JsonSlice<'a>> {
        if !self.inside {
            return None;
        }
        let mut s = Scan { bytes: self.bytes, pos: self.pos };
        s.skip_ws();
        match s.peek() {
            None | Some(b']') => {
                self.inside = false;
                return None;
            }
            _ => {}
        }
        let start = s.pos;
        // The enclosing document was validated by `JsonSlice::parse`, so
        // a scan failure here is unreachable; treat it as end-of-array.
        if s.skip_value(0).is_err() {
            self.inside = false;
            return None;
        }
        let end = s.pos;
        s.skip_ws();
        match s.peek() {
            Some(b',') => self.pos = s.pos + 1,
            _ => {
                // ']' (or exhausted input): this element is the last.
                self.pos = s.pos;
                self.inside = false;
            }
        }
        Some(JsonSlice { bytes: &self.bytes[start..end] })
    }
}

/// Iterator over the fields of a [`JsonSlice`] object (see
/// [`JsonSlice::fields`]).
pub struct JsonFields<'a> {
    bytes: &'a [u8],
    pos: usize,
    inside: bool,
}

impl<'a> Iterator for JsonFields<'a> {
    type Item = (&'a [u8], JsonSlice<'a>);

    fn next(&mut self) -> Option<(&'a [u8], JsonSlice<'a>)> {
        if !self.inside {
            return None;
        }
        let mut s = Scan { bytes: self.bytes, pos: self.pos };
        s.skip_ws();
        match s.peek() {
            None | Some(b'}') => {
                self.inside = false;
                return None;
            }
            _ => {}
        }
        // The enclosing document was validated by `JsonSlice::parse`, so
        // scan failures are unreachable; treat them as end-of-object.
        let kspan = match s.string_span() {
            Ok(k) => k,
            Err(_) => {
                self.inside = false;
                return None;
            }
        };
        s.skip_ws();
        if s.peek() != Some(b':') {
            self.inside = false;
            return None;
        }
        s.pos += 1;
        s.skip_ws();
        let vstart = s.pos;
        if s.skip_value(0).is_err() {
            self.inside = false;
            return None;
        }
        let vend = s.pos;
        s.skip_ws();
        match s.peek() {
            Some(b',') => self.pos = s.pos + 1,
            _ => {
                self.pos = s.pos;
                self.inside = false;
            }
        }
        Some((kspan, JsonSlice { bytes: &self.bytes[vstart..vend] }))
    }
}

/// Decode the inner bytes of a JSON string literal. Borrowed when no
/// escapes are present.
fn unescape(inner: &[u8]) -> Option<Cow<'_, str>> {
    if !inner.contains(&b'\\') {
        return std::str::from_utf8(inner).ok().map(Cow::Borrowed);
    }
    let mut out = String::with_capacity(inner.len());
    let mut i = 0;
    while i < inner.len() {
        if inner[i] == b'\\' {
            let esc = *inner.get(i + 1)?;
            i += 2;
            match esc {
                b'n' => out.push('\n'),
                b't' => out.push('\t'),
                b'r' => out.push('\r'),
                b'"' => out.push('"'),
                b'\\' => out.push('\\'),
                b'/' => out.push('/'),
                b'b' => out.push('\u{8}'),
                b'f' => out.push('\u{c}'),
                b'u' => {
                    let hex = inner.get(i..i + 4)?;
                    let code =
                        u32::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
                    i += 4;
                    let c = if (0xD800..=0xDBFF).contains(&code) {
                        // High surrogate: must combine with a following
                        // low surrogate (standard ensure_ascii encoders
                        // emit non-BMP chars as pairs). Replacing each
                        // half with U+FFFD would alias distinct ids.
                        if inner.get(i) != Some(&b'\\') || inner.get(i + 1) != Some(&b'u') {
                            return None;
                        }
                        let hex2 = inner.get(i + 2..i + 6)?;
                        let low =
                            u32::from_str_radix(std::str::from_utf8(hex2).ok()?, 16).ok()?;
                        if !(0xDC00..=0xDFFF).contains(&low) {
                            return None;
                        }
                        i += 6;
                        char::from_u32(0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00))?
                    } else {
                        // Lone low surrogates are rejected, not replaced.
                        char::from_u32(code)?
                    };
                    out.push(c);
                }
                _ => return None,
            }
        } else {
            // Consume one UTF-8 scalar.
            let rest = std::str::from_utf8(&inner[i..]).ok()?;
            let c = rest.chars().next()?;
            out.push(c);
            i += c.len_utf8();
        }
    }
    Some(Cow::Owned(out))
}

/// Compare a string literal's inner span against a plain key without
/// allocating. Escaped keys fall back to full decoding (rare).
fn string_content_eq(inner: &[u8], key: &str) -> bool {
    if !inner.contains(&b'\\') {
        return inner == key.as_bytes();
    }
    matches!(unescape(inner), Some(s) if s == key)
}

/// Allocation-free well-formedness scanner over raw JSON bytes.
struct Scan<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Scan<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    /// Skip one string literal, returning its inner (undecoded) span.
    fn string_span(&mut self) -> Result<&'a [u8], String> {
        if self.peek() != Some(b'"') {
            return Err(format!("expected string at byte {}", self.pos));
        }
        self.pos += 1;
        let start = self.pos;
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    let span = &self.bytes[start..self.pos];
                    self.pos += 1;
                    return Ok(span);
                }
                Some(b'\\') => {
                    // The escaped byte is validated on decode; here we
                    // only need to not treat an escaped quote as the end.
                    self.pos += 2;
                    if self.pos > self.bytes.len() {
                        return Err("unterminated escape".into());
                    }
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    fn skip_literal(&mut self, word: &[u8]) -> Result<(), String> {
        if self.bytes[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn skip_number(&mut self) -> Result<(), String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("bad number at byte {start}"))?;
        text.parse::<f64>()
            .map(|_| ())
            .map_err(|_| format!("bad number at byte {start}"))
    }

    /// Skip exactly one JSON value, validating structure.
    fn skip_value(&mut self, depth: usize) -> Result<(), String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".into());
        }
        match self.peek() {
            Some(b'"') => self.string_span().map(|_| ()),
            Some(b't') => self.skip_literal(b"true"),
            Some(b'f') => self.skip_literal(b"false"),
            Some(b'n') => self.skip_literal(b"null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.skip_number(),
            Some(b'[') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_ws();
                    self.skip_value(depth + 1)?;
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("expected , or ] at byte {}", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_ws();
                    self.string_span()?;
                    self.skip_ws();
                    if self.peek() != Some(b':') {
                        return Err(format!("expected ':' at byte {}", self.pos));
                    }
                    self.pos += 1;
                    self.skip_ws();
                    self.skip_value(depth + 1)?;
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("expected , or }} at byte {}", self.pos)),
                    }
                }
            }
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            )),
        }
    }
}

/// Streaming JSON serializer writing into a caller-owned `Vec<u8>`. With
/// a reused buffer the steady state performs zero heap allocations: the
/// buffer grows to its high-water mark once and is then only overwritten.
pub struct JsonWriter<'a> {
    out: &'a mut Vec<u8>,
    /// Comma state per nesting level (bit set once a container has
    /// entries) — a bitset so the writer itself never allocates.
    comma: u64,
    depth: usize,
}

/// `JsonWriter` nesting ceiling (bitset width). Exceeding it is a
/// programmer error and panics loudly rather than silently emitting
/// malformed JSON.
const MAX_WRITER_DEPTH: usize = 64;

impl<'a> JsonWriter<'a> {
    /// Append to `out` (callers `clear()` it between messages).
    pub fn new(out: &'a mut Vec<u8>) -> JsonWriter<'a> {
        JsonWriter { out, comma: 0, depth: 0 }
    }

    fn elem(&mut self) {
        if self.comma >> self.depth & 1 == 1 {
            self.out.push(b',');
        }
        self.comma |= 1 << self.depth;
    }

    fn descend(&mut self) {
        self.depth += 1;
        assert!(
            self.depth < MAX_WRITER_DEPTH,
            "JsonWriter nesting exceeds {MAX_WRITER_DEPTH} levels"
        );
        self.comma &= !(1 << self.depth);
    }

    pub fn begin_obj(&mut self) {
        self.elem();
        self.out.push(b'{');
        self.descend();
    }

    pub fn end_obj(&mut self) {
        self.out.push(b'}');
        self.depth = self.depth.saturating_sub(1);
    }

    pub fn begin_arr(&mut self) {
        self.elem();
        self.out.push(b'[');
        self.descend();
    }

    pub fn end_arr(&mut self) {
        self.out.push(b']');
        self.depth = self.depth.saturating_sub(1);
    }

    /// Object key. The caller must follow with exactly one value.
    pub fn key(&mut self, k: &str) {
        self.elem();
        escape_into(k, self.out);
        self.out.push(b':');
        // The value that follows completes this element rather than
        // starting a new one: suppress its comma.
        self.comma &= !(1 << self.depth);
    }

    pub fn str_val(&mut self, s: &str) {
        self.elem();
        escape_into(s, self.out);
    }

    /// Numbers render like [`Json::to_string`]: integral values without a
    /// fraction, everything else via the shortest `f64` display form.
    pub fn num_val(&mut self, n: f64) {
        use std::io::Write as _;
        self.elem();
        if n.fract() == 0.0 && n.abs() < 1e15 {
            let _ = write!(self.out, "{}", n as i64);
        } else {
            let _ = write!(self.out, "{n}");
        }
    }

    pub fn bool_val(&mut self, b: bool) {
        self.elem();
        self.out.extend_from_slice(if b { b"true" as &[u8] } else { b"false" });
    }

    pub fn null_val(&mut self) {
        self.elem();
        self.out.extend_from_slice(b"null");
    }

    /// `"key": "string"` convenience.
    pub fn field_str(&mut self, k: &str, v: &str) {
        self.key(k);
        self.str_val(v);
    }

    /// `"key": number` convenience.
    pub fn field_num(&mut self, k: &str, v: f64) {
        self.key(k);
        self.num_val(v);
    }

    /// `"key": bool` convenience.
    pub fn field_bool(&mut self, k: &str, v: bool) {
        self.key(k);
        self.bool_val(v);
    }
}

/// Escape a string into UTF-8 bytes (same rules as [`write_escaped`]).
fn escape_into(s: &str, out: &mut Vec<u8>) {
    use std::io::Write as _;
    out.push(b'"');
    for c in s.chars() {
        match c {
            '"' => out.extend_from_slice(b"\\\""),
            '\\' => out.extend_from_slice(b"\\\\"),
            '\n' => out.extend_from_slice(b"\\n"),
            '\t' => out.extend_from_slice(b"\\t"),
            '\r' => out.extend_from_slice(b"\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => {
                let mut buf = [0u8; 4];
                out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
            }
        }
    }
    out.push(b'"');
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    /// Four hex digits starting at `start`.
    fn hex4(&self, start: usize) -> Result<u32, String> {
        let hex = self
            .bytes
            .get(start..start + 4)
            .ok_or_else(|| "bad \\u escape".to_string())?;
        let text = std::str::from_utf8(hex).map_err(|_| "bad \\u".to_string())?;
        u32::from_str_radix(text, 16).map_err(|_| "bad \\u".to_string())
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let code = self.hex4(self.pos + 1)?;
                            self.pos += 4;
                            let c = if (0xD800..=0xDBFF).contains(&code) {
                                // Combine surrogate pairs (see `unescape`).
                                if self.bytes.get(self.pos + 1) != Some(&b'\\')
                                    || self.bytes.get(self.pos + 2) != Some(&b'u')
                                {
                                    return Err("lone high surrogate".into());
                                }
                                let low = self.hex4(self.pos + 3)?;
                                if !(0xDC00..=0xDFFF).contains(&low) {
                                    return Err("invalid low surrogate".into());
                                }
                                self.pos += 6;
                                char::from_u32(0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00))
                                    .ok_or("bad surrogate pair")?
                            } else {
                                char::from_u32(code).ok_or("lone low surrogate")?
                            };
                            out.push(c);
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"t":true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        // Surrogate pairs combine into one scalar (ensure_ascii
        // encoders emit non-BMP chars this way)...
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("\u{1F600}".into())
        );
        // ...and lone surrogates are rejected, never U+FFFD-aliased.
        assert!(Json::parse("\"\\ud83d\"").is_err());
        assert!(Json::parse("\"\\ude00\"").is_err());
        assert!(Json::parse("\"\\ud83dx\"").is_err());
    }

    #[test]
    fn slice_unicode_escape_matches_tree() {
        let v = JsonSlice::parse(b"{\"id\":\"\\ud83d\\ude00\"}").unwrap();
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), "\u{1F600}");
        let lone = JsonSlice::parse(b"{\"id\":\"\\ud83d\"}").unwrap();
        assert_eq!(lone.get("id").unwrap().as_str(), None);
    }

    #[test]
    fn as_usize_is_strict() {
        assert_eq!(Json::Num(216.0).as_usize(), Some(216));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Str("3".into()).as_usize(), None);
    }

    #[test]
    fn slice_reads_flat_objects_without_copying() {
        let body = br#"{"client_id":"lg-7","app":"clomp","alpha":0.8,"arm":42,"ok":true,"x":null}"#;
        let v = JsonSlice::parse(body).unwrap();
        let cid = v.get("client_id").unwrap().as_str().unwrap();
        assert_eq!(cid, "lg-7");
        assert!(matches!(cid, Cow::Borrowed(_)), "plain strings must borrow");
        assert_eq!(v.get("app").unwrap().as_str().unwrap(), "clomp");
        assert_eq!(v.get("alpha").unwrap().as_f64(), Some(0.8));
        assert_eq!(v.get("arm").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert!(v.get("x").unwrap().is_null());
        assert!(v.get("missing").is_none());
        assert!(v.is_obj());
    }

    #[test]
    fn slice_handles_escapes_and_nesting() {
        let body = br#"{"aA":"x","s":"tab\there","o":{"inner":[1,2,{"d":3}]}}"#;
        let v = JsonSlice::parse(body).unwrap();
        assert_eq!(v.get("aA").unwrap().as_str().unwrap(), "x");
        let s = v.get("s").unwrap().as_str().unwrap();
        assert_eq!(s, "tab\there");
        assert!(matches!(s, Cow::Owned(_)), "escaped strings must decode");
        let inner = v.get("o").unwrap().get("inner").unwrap();
        assert_eq!(inner.raw()[0], b'[');
    }

    #[test]
    fn slice_iterates_arrays() {
        let body = br#"{"arms":[3, 7, 12],"counts":[4.5,9,1],"empty":[],"nested":[[1],{"a":2}]}"#;
        let v = JsonSlice::parse(body).unwrap();
        let arms: Vec<usize> =
            v.get("arms").unwrap().items().filter_map(|e| e.as_usize()).collect();
        assert_eq!(arms, vec![3, 7, 12]);
        let counts: Vec<f64> =
            v.get("counts").unwrap().items().filter_map(|e| e.as_f64()).collect();
        assert_eq!(counts, vec![4.5, 9.0, 1.0]);
        let empty = v.get("empty").unwrap();
        assert!(empty.is_arr());
        assert_eq!(empty.items().count(), 0);
        let nested: Vec<JsonSlice<'_>> = v.get("nested").unwrap().items().collect();
        assert_eq!(nested.len(), 2);
        assert!(nested[0].is_arr());
        assert_eq!(nested[1].get("a").unwrap().as_f64(), Some(2.0));
        // Non-arrays neither claim to be arrays nor yield elements.
        let scalar = v.get("arms").unwrap().items().next().unwrap();
        assert!(!scalar.is_arr());
        assert_eq!(scalar.items().count(), 0);
    }

    #[test]
    fn slice_iterates_object_fields_in_order() {
        let body = br#"{"client_id":"a","arm":3,"nested":{"x":1},"arr":[1,2]}"#;
        let v = JsonSlice::parse(body).unwrap();
        let fields: Vec<(&[u8], JsonSlice<'_>)> = v.fields().collect();
        assert_eq!(fields.len(), 4);
        assert_eq!(fields[0].0, b"client_id");
        assert_eq!(fields[0].1.as_str().unwrap(), "a");
        assert_eq!(fields[1].0, b"arm");
        assert_eq!(fields[1].1.as_usize(), Some(3));
        assert_eq!(fields[2].0, b"nested");
        assert!(fields[2].1.is_obj());
        assert_eq!(fields[3].0, b"arr");
        assert!(fields[3].1.is_arr());
        // Non-objects and empty objects yield nothing.
        assert_eq!(fields[3].1.fields().count(), 0);
        assert_eq!(JsonSlice::parse(b"{}").unwrap().fields().count(), 0);
    }

    #[test]
    fn duplicate_keys_are_detected() {
        let dup = JsonSlice::parse(br#"{"a":1,"b":2,"a":3}"#).unwrap();
        assert!(dup.has_duplicate_keys());
        let clean = JsonSlice::parse(br#"{"a":1,"b":2,"c":3}"#).unwrap();
        assert!(!clean.has_duplicate_keys());
        // Escape-aware: "\u0061" spells the same key as "a".
        let escaped = JsonSlice::parse(br#"{"\u0061":1,"a":2}"#).unwrap();
        assert!(escaped.has_duplicate_keys());
        // Only the top level is checked; nested objects are separate.
        let nested = JsonSlice::parse(br#"{"a":{"x":1},"b":{"x":2}}"#).unwrap();
        assert!(!nested.has_duplicate_keys());
        assert!(!JsonSlice::parse(b"[1,2]").unwrap().has_duplicate_keys());
    }

    #[test]
    fn slice_rejects_malformed_documents() {
        assert!(JsonSlice::parse(b"{").is_err());
        assert!(JsonSlice::parse(b"[1,]").is_err());
        assert!(JsonSlice::parse(b"12 34").is_err());
        assert!(JsonSlice::parse(b"{'a': 1}").is_err());
        assert!(JsonSlice::parse(b"\"unterminated").is_err());
        // Deep nesting is bounded, not a stack overflow.
        let deep = [b'['; 10_000];
        assert!(JsonSlice::parse(&deep).is_err());
    }

    #[test]
    fn slice_rejects_invalid_utf8_strings() {
        let mut body = b"{\"k\":\"".to_vec();
        body.push(0xFF);
        body.extend_from_slice(b"\"}");
        // The scan is byte-level so parse succeeds, but string access
        // refuses to lossy-decode.
        if let Ok(v) = JsonSlice::parse(&body) {
            assert!(v.get("k").unwrap().as_str().is_none());
        }
    }

    #[test]
    fn writer_matches_tree_serialization() {
        let mut buf = Vec::new();
        let mut w = JsonWriter::new(&mut buf);
        w.begin_obj();
        w.field_num("arm", 42.0);
        w.field_str("config", "omp=4 \"quoted\"");
        w.field_bool("queued", true);
        w.key("quantiles");
        w.begin_arr();
        w.num_val(0.5);
        w.num_val(0.99);
        w.end_arr();
        w.key("nested");
        w.begin_obj();
        w.field_num("n", 1.25);
        w.key("none");
        w.null_val();
        w.end_obj();
        w.end_obj();
        let text = String::from_utf8(buf).unwrap();
        // Round-trips through the tree parser to an identical document.
        let tree = Json::parse(&text).unwrap();
        assert_eq!(tree.get("arm").and_then(Json::as_usize), Some(42));
        assert_eq!(tree.get("config").and_then(Json::as_str), Some("omp=4 \"quoted\""));
        assert_eq!(tree.get("queued").and_then(Json::as_bool), Some(true));
        let q = tree.get("quantiles").unwrap().as_arr().unwrap();
        assert_eq!(q, &[Json::Num(0.5), Json::Num(0.99)][..]);
        assert_eq!(
            tree.get("nested").and_then(|n| n.get("n")).and_then(Json::as_f64),
            Some(1.25)
        );
    }

    #[test]
    fn writer_reuses_buffer_without_realloc() {
        let mut buf = Vec::with_capacity(256);
        for i in 0..100 {
            buf.clear();
            let ptr = buf.as_ptr();
            let mut w = JsonWriter::new(&mut buf);
            w.begin_obj();
            w.field_num("round", i as f64);
            w.field_str("config", "omp_threads=8 tiling=2");
            w.end_obj();
            assert_eq!(buf.as_ptr(), ptr, "steady-state write must not realloc");
        }
    }

    #[test]
    fn parses_real_manifest_shape() {
        let m = r#"{"format": "hlo-text", "return_tuple": true,
          "artifacts": [{"name": "lasp_step_kripke", "file": "lasp_step_kripke.hlo.txt",
            "inputs": [{"shape": [216], "dtype": "f32"}], "outputs": [{"shape": [], "dtype": "s32"}],
            "kind": "lasp_step", "k": 216, "app": "kripke"}]}"#;
        let v = Json::parse(m).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("k").unwrap().as_usize(), Some(216));
    }
}
