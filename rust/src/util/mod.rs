//! Small shared utilities: deterministic RNG, statistics helpers.

pub mod rng;
pub mod json;
pub mod stats;

pub use rng::Rng;
