//! Deterministic, dependency-free PRNG (splitmix64 seeding + xoshiro256**).
//!
//! Every stochastic component in the simulator takes an explicit [`Rng`] so
//! experiments are reproducible from a single seed; streams can be forked
//! per device / per run without correlation.

/// xoshiro256** PRNG with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Fork an independent stream (e.g. one per simulated device).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Multiply-shift rejection-free mapping; bias is negligible for
        // n << 2^64 (largest space here is 92,160).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Symmetric relative noise factor: `1 + U(-pct, +pct)`.
    pub fn relative_noise(&mut self, pct: f64) -> f64 {
        1.0 + self.range(-pct, pct)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            // Rejection sampling for sparse draws from large spaces.
            let mut seen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(92_160, 64);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 64);
        let t = r.sample_indices(10, 10);
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn forked_streams_uncorrelated() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let corr: f64 = (0..1000)
            .map(|_| (a.uniform() - 0.5) * (b.uniform() - 0.5))
            .sum::<f64>()
            / 1000.0;
        assert!(corr.abs() < 0.02, "corr {corr}");
    }
}
