//! Tiny statistics helpers shared by experiments and telemetry.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// `q`-quantile (0..=1) by linear interpolation on a sorted copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (v.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    let frac = pos - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// MinMax normalization into `[0, 1]` (paper Alg. 1 line 2). Degenerate
/// ranges map to all-zeros.
pub fn minmax(xs: &[f64]) -> Vec<f64> {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let range = (hi - lo).max(1e-9);
    xs.iter().map(|x| (x - lo) / range).collect()
}

/// Index of the minimum element (ties: first, matching `jnp.argmin`).
pub fn argmin(xs: &[f64]) -> usize {
    let mut best = 0;
    let mut best_v = f64::INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x < best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

/// Index of the maximum element (ties: first, matching `jnp.argmax` — the
/// AOT artifacts and the fused scalar backend must agree on ties).
pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    let mut best_v = f64::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

/// Indices of the `k` smallest elements, ascending.
pub fn bottom_k(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    idx.truncate(k);
    idx
}

/// Pearson correlation of two equal-length slices.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - ma) * (y - mb);
        da += (x - ma) * (x - ma);
        db += (y - mb) * (y - mb);
    }
    num / (da.sqrt() * db.sqrt()).max(1e-12)
}

/// Simple fixed-width ASCII histogram, used by the CLI experiment output.
pub fn histogram(xs: &[f64], bins: usize) -> Vec<(f64, f64, usize)> {
    if xs.is_empty() || bins == 0 {
        return vec![];
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let width = ((hi - lo) / bins as f64).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x - lo) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    counts
        .into_iter()
        .enumerate()
        .map(|(i, c)| (lo + i as f64 * width, lo + (i + 1) as f64 * width, c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!(std_dev(&[2.0, 2.0, 2.0]) < 1e-12);
        assert!((std_dev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn minmax_bounds() {
        let v = minmax(&[5.0, 10.0, 7.5]);
        assert_eq!(v, vec![0.0, 1.0, 0.5]);
        // Degenerate range: all zeros, no NaN.
        let d = minmax(&[3.0, 3.0]);
        assert!(d.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn arg_and_topk() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(argmin(&xs), 1);
        assert_eq!(argmax(&xs), 0);
        assert_eq!(bottom_k(&xs, 2), vec![1, 2]);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 4.0, 6.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_counts_sum() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let h = histogram(&xs, 10);
        assert_eq!(h.iter().map(|(_, _, c)| c).sum::<usize>(), 100);
        assert_eq!(h.len(), 10);
    }
}
