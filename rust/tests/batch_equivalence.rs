//! Differential proof that the batched suggest/report path is *exactly*
//! the single-request path, bit for bit, at every layer:
//!
//! 1. **Policy layer** — for every `PolicyKind`, a fleet driven through
//!    [`select_batch`] (one shared scratch) must produce the identical
//!    [`Choice`] stream — arm, `gap` bits, `explore` flag — and identical
//!    final `ArmStats` as the same fleet driven through per-session
//!    `select_traced()` calls in the same order.
//! 2. **Kernel layer** — the autovectorization-friendly forms of
//!    `weighted_rewards_into` / `ucb_scores_into` (branch-free selects,
//!    lane-split min/max, chunked tails) are pinned bit-for-bit against
//!    frozen scalar reference implementations in the style of
//!    `policy_golden.rs`: plain branchy loops, single accumulators,
//!    left-to-right order.
//! 3. **HTTP layer** — two live servers, one fed single
//!    `/v1/suggest`+`/v1/report` requests, the other the equivalent
//!    `/v1/suggest/batch`+`/v1/report/batch` stream (same client ids, so
//!    session-key-hash-seeded stochastic policies line up), must emit the
//!    same arm sequences and converge to identical per-session arm
//!    statistics.

use lasp::bandit::reward::{
    ucb_scores_into, weighted_rewards, weighted_rewards_into, MINMAX_EPS, REWARD_EPS,
    UNPULLED_SCORE,
};
use lasp::bandit::{
    select_batch, ArmStats, Choice, EpsilonGreedy, Policy, Scratch, SlidingWindowUcb, SubsetTuner,
    ThompsonSampler, UcbTuner,
};
use lasp::serve::{start, HttpClient, ServeConfig};
use lasp::util::json::{Json, JsonSlice};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

const ALPHA: f64 = 0.7;
const BETA: f64 = 0.3;

// --- 1. Policy layer ------------------------------------------------------

fn fleet(kind: &str, n: usize) -> Vec<Box<dyn Policy>> {
    let k = 16;
    (0..n)
        .map(|i| {
            let seed = 31 * i as u64 + 7;
            let b: Box<dyn Policy> = match kind {
                "ucb" => Box::new(UcbTuner::new(k, ALPHA, BETA)),
                "swucb" => Box::new(SlidingWindowUcb::new(k, ALPHA, BETA, 48)),
                "thompson" => Box::new(ThompsonSampler::new(k, ALPHA, BETA, seed)),
                "epsilon" => Box::new(EpsilonGreedy::new(k, ALPHA, BETA, 0.1, seed)),
                "subset" => Box::new(SubsetTuner::new(500, 24, ALPHA, BETA, seed)),
                _ => unreachable!(),
            };
            b
        })
        .collect()
}

fn measurement(arm: usize, round: usize) -> (f64, f64) {
    // Deterministic, positive, arm-dependent — no RNG, so both streams
    // feed byte-identical updates whenever the arms agree.
    (
        0.5 + ((arm * 7919 + round * 13) % 97) as f64 / 40.0,
        3.0 + ((arm * 104_729) % 11) as f64 * 0.5,
    )
}

fn assert_choice_bits(name: &str, round: usize, i: usize, single: &Choice, batched: &Choice) {
    assert_eq!(batched.arm, single.arm, "{name}: arm diverged (round {round}, session {i})");
    assert_eq!(
        batched.gap.to_bits(),
        single.gap.to_bits(),
        "{name}: gap bits diverged (round {round}, session {i}): {} vs {}",
        batched.gap,
        single.gap
    );
    assert_eq!(
        batched.explore, single.explore,
        "{name}: explore flag diverged (round {round}, session {i})"
    );
}

#[test]
fn batched_stream_is_bit_identical_to_interleaved_singles_for_every_policy() {
    let (sessions, rounds) = (6usize, 120usize);
    for kind in ["ucb", "swucb", "thompson", "epsilon", "subset"] {
        let mut singles = fleet(kind, sessions);
        let mut batched = fleet(kind, sessions);
        let mut scratch = Scratch::new();
        let mut choices: Vec<Choice> = Vec::new();
        for round in 0..rounds {
            // Single-request stream: suggest+report per session, in order.
            let mut single_choices = Vec::with_capacity(sessions);
            for s in singles.iter_mut() {
                let c = s.select_traced();
                let (t, p) = measurement(c.arm, round);
                s.update(c.arm, t, p);
                single_choices.push(c);
            }
            // Batched stream: one multi-session select through ONE shared
            // scratch, then the same reports.
            {
                let mut refs: Vec<&mut dyn Policy> =
                    batched.iter_mut().map(|b| b.as_mut()).collect();
                select_batch(&mut refs, &mut scratch, &mut choices);
            }
            assert_eq!(choices.len(), sessions);
            for (i, c) in choices.iter().enumerate() {
                assert_choice_bits(kind, round, i, &single_choices[i], c);
                let (t, p) = measurement(c.arm, round);
                batched[i].update(c.arm, t, p);
            }
        }
        // Identical decision streams must leave identical sufficient
        // statistics (ArmStats: PartialEq over every f64 field).
        for (i, (a, b)) in singles.iter().zip(&batched).enumerate() {
            assert_eq!(
                b.stats(),
                a.stats(),
                "{kind}: final ArmStats diverged for session {i}"
            );
            assert_eq!(b.counts(), a.counts(), "{kind}: full-space counts diverged ({i})");
            assert_eq!(b.total_pulls(), a.total_pulls(), "{kind}");
        }
    }
}

// --- 2. Kernel layer ------------------------------------------------------
// Frozen scalar references: plain branchy loops, single min/max
// accumulators, strict left-to-right order. If a future "optimization"
// reassociates the fill sums or turns a select back into a value-changing
// branch, these diverge bit-for-bit.

fn ref_weighted_rewards(stats: &ArmStats, alpha: f64, beta: f64) -> Vec<f64> {
    let k = stats.k();
    let counts = stats.counts();
    let mean_tau = stats.mean_tau();
    let mean_rho = stats.mean_rho();
    let mut fill_tau = 0.0;
    let mut fill_rho = 0.0;
    let mut pulled = 0.0f64;
    let mut tau_lo = f64::INFINITY;
    let mut tau_hi = f64::NEG_INFINITY;
    let mut rho_lo = f64::INFINITY;
    let mut rho_hi = f64::NEG_INFINITY;
    for i in 0..k {
        if counts[i] > 0.0 {
            fill_tau += mean_tau[i];
            fill_rho += mean_rho[i];
            pulled += 1.0;
            tau_lo = tau_lo.min(mean_tau[i]);
            tau_hi = tau_hi.max(mean_tau[i]);
            rho_lo = rho_lo.min(mean_rho[i]);
            rho_hi = rho_hi.max(mean_rho[i]);
        }
    }
    let denom = pulled.max(1.0);
    let fill_tau = fill_tau / denom;
    let fill_rho = fill_rho / denom;
    if pulled == 0.0 {
        tau_lo = fill_tau;
        tau_hi = fill_tau;
        rho_lo = fill_rho;
        rho_hi = fill_rho;
    }
    let tau_range = (tau_hi - tau_lo).max(MINMAX_EPS);
    let rho_range = (rho_hi - rho_lo).max(MINMAX_EPS);

    let mut out = vec![0.0f64; k];
    let mut raw_lo = f64::INFINITY;
    let mut raw_hi = f64::NEG_INFINITY;
    for i in 0..k {
        let (mt, mr) = if counts[i] > 0.0 {
            (mean_tau[i], mean_rho[i])
        } else {
            (fill_tau, fill_rho)
        };
        let tau_hat = (mt - tau_lo) / tau_range;
        let rho_hat = (mr - rho_lo) / rho_range;
        let raw = alpha / (tau_hat + REWARD_EPS) + beta / (rho_hat + REWARD_EPS);
        out[i] = raw;
        raw_lo = raw_lo.min(raw);
        raw_hi = raw_hi.max(raw);
    }
    let raw_range = (raw_hi - raw_lo).max(MINMAX_EPS);
    for r in out.iter_mut() {
        *r = (*r - raw_lo) / raw_range;
    }
    out
}

fn ref_ucb_scores(rewards: &[f64], counts: &[f64], t: f64, c: f64) -> Vec<f64> {
    let log_t = t.max(1.0).ln();
    rewards
        .iter()
        .zip(counts)
        .map(|(r, n)| {
            if *n > 0.0 {
                r + c * (2.0 * log_t / n.max(1.0)).sqrt()
            } else {
                UNPULLED_SCORE
            }
        })
        .collect()
}

/// Deterministic stats fixtures: k spans the lane width (1, tail-only),
/// exact multiples, and off-by-tail sizes; `pulled_every` leaves gaps of
/// unpulled arms (0 = pull nothing).
fn stats_fixture(k: usize, pulled_every: usize, seed: usize) -> ArmStats {
    let mut s = ArmStats::new(k);
    if pulled_every == 0 {
        return s;
    }
    for i in (0..k).step_by(pulled_every) {
        for pull in 0..1 + (i + seed) % 3 {
            let t = 0.3 + ((i * 7919 + pull * 31 + seed) % 89) as f64 / 30.0;
            let p = 2.0 + ((i * 104_729 + pull) % 13) as f64 * 0.4;
            s.observe(i, t, p);
        }
    }
    s
}

#[test]
fn vectorized_kernels_match_frozen_scalar_references_bit_for_bit() {
    for &k in &[1usize, 3, 4, 7, 8, 9, 31, 64, 216] {
        for &pulled_every in &[0usize, 1, 2, 3, 5] {
            let stats = stats_fixture(k, pulled_every, k + pulled_every);
            let expected = ref_weighted_rewards(&stats, ALPHA, BETA);
            let mut got = vec![0.0f64; k];
            weighted_rewards_into(&stats, ALPHA, BETA, &mut got);
            for i in 0..k {
                assert_eq!(
                    got[i].to_bits(),
                    expected[i].to_bits(),
                    "weighted_rewards_into k={k} pulled_every={pulled_every} arm {i}: \
                     {} vs {}",
                    got[i],
                    expected[i]
                );
            }
            // The documented bridge to the allocating offline form holds
            // bit-for-bit too.
            let (mt, mr) = stats.filled_means();
            let offline = weighted_rewards(&mt, &mr, ALPHA, BETA);
            for i in 0..k {
                assert_eq!(
                    got[i].to_bits(),
                    offline[i].to_bits(),
                    "weighted_rewards_into vs weighted_rewards k={k} arm {i}"
                );
            }

            let t = stats.t();
            let expected_scores = ref_ucb_scores(&got, stats.counts(), t, 0.25);
            let mut scores = vec![0.0f64; k];
            ucb_scores_into(&got, stats.counts(), t, 0.25, &mut scores);
            for i in 0..k {
                assert_eq!(
                    scores[i].to_bits(),
                    expected_scores[i].to_bits(),
                    "ucb_scores_into k={k} pulled_every={pulled_every} arm {i}"
                );
            }
        }
    }
}

// --- 3. HTTP layer --------------------------------------------------------

fn boot() -> lasp::serve::ServerHandle {
    start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 4,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .unwrap()
}

struct Entry {
    client_id: String,
    policy: &'static str,
}

fn entry_obj(e: &Entry, report: Option<(usize, f64, f64, u64)>) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(e.client_id.clone()));
    obj.insert("app".to_string(), Json::Str("clomp".to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    obj.insert("policy".to_string(), Json::Str(e.policy.to_string()));
    obj.insert("alpha".to_string(), Json::Num(ALPHA));
    obj.insert("beta".to_string(), Json::Num(BETA));
    if let Some((arm, t, p, seq)) = report {
        obj.insert("arm".to_string(), Json::Num(arm as f64));
        obj.insert("time_s".to_string(), Json::Num(t));
        obj.insert("power_w".to_string(), Json::Num(p));
        obj.insert("seq".to_string(), Json::Num(seq as f64));
    }
    Json::Obj(obj)
}

fn metric_value(text: &str, name: &str) -> f64 {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Some(v) = rest.strip_prefix(' ').and_then(|r| r.trim().parse::<f64>().ok()) {
                return v;
            }
        }
    }
    0.0
}

fn wait_applied(client: &mut HttpClient, want: f64, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (status, page) = client.get("/metrics").unwrap();
        assert_eq!(status, 200);
        let text = page.as_str().unwrap_or_default().to_string();
        if metric_value(&text, "lasp_serve_reports_applied_total") >= want {
            return;
        }
        assert!(Instant::now() < deadline, "{what}: reports never applied (want {want})");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn http_batch_endpoints_match_single_request_stream() {
    let single_srv = boot();
    let batch_srv = boot();
    let mut sc = HttpClient::connect(&single_srv.addr().to_string()).unwrap();
    let mut bc = HttpClient::connect(&batch_srv.addr().to_string()).unwrap();

    // Two clients per policy: stochastic tuners are seeded by the
    // session-key hash, so identical keys on both servers mean identical
    // RNG streams.
    let entries: Vec<Entry> = ["ucb", "swucb", "thompson", "epsilon", "subset"]
        .iter()
        .flat_map(|&p| {
            (0..2).map(move |i| Entry { client_id: format!("eq-{p}-{i}"), policy: p })
        })
        .collect();
    let n = entries.len();

    let rounds = 8usize;
    for round in 0..rounds {
        // Suggest: singles on server A, one batch on server B.
        let mut single_arms = Vec::with_capacity(n);
        for e in &entries {
            let payload = entry_obj(e, None).to_string();
            let status = sc.post_slice("/v1/suggest", payload.as_bytes()).unwrap();
            assert_eq!(status, 200);
            let arm = JsonSlice::parse(sc.last_body())
                .unwrap()
                .get("arm")
                .and_then(|v| v.as_usize())
                .unwrap();
            single_arms.push(arm);
        }
        let batch_body = {
            let mut obj = BTreeMap::new();
            obj.insert(
                "entries".to_string(),
                Json::Arr(entries.iter().map(|e| entry_obj(e, None)).collect()),
            );
            Json::Obj(obj).to_string()
        };
        let status = bc.post_slice("/v1/suggest/batch", batch_body.as_bytes()).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(bc.last_body()));
        let resp = JsonSlice::parse(bc.last_body()).unwrap();
        let mut batch_arms = Vec::with_capacity(n);
        for item in resp.get("results").expect("results").items() {
            batch_arms.push(item.get("arm").and_then(|v| v.as_usize()).unwrap());
        }
        assert_eq!(
            batch_arms, single_arms,
            "round {round}: batched suggests diverged from singles"
        );

        // Report the same deterministic measurements on both.
        for (e, &arm) in entries.iter().zip(&single_arms) {
            let (t, p) = measurement(arm, round);
            let payload = entry_obj(e, Some((arm, t, p, round as u64))).to_string();
            let status = sc.post_slice("/v1/report", payload.as_bytes()).unwrap();
            assert_eq!(status, 202);
        }
        let report_body = {
            let mut obj = BTreeMap::new();
            obj.insert(
                "entries".to_string(),
                Json::Arr(
                    entries
                        .iter()
                        .zip(&single_arms)
                        .map(|(e, &arm)| {
                            let (t, p) = measurement(arm, round);
                            entry_obj(e, Some((arm, t, p, round as u64)))
                        })
                        .collect(),
                ),
            );
            Json::Obj(obj).to_string()
        };
        let status = bc.post_slice("/v1/report/batch", report_body.as_bytes()).unwrap();
        assert_eq!(status, 202, "{}", String::from_utf8_lossy(bc.last_body()));
        let resp = JsonSlice::parse(bc.last_body()).unwrap();
        assert_eq!(resp.get("queued").and_then(|v| v.as_usize()), Some(n));
        assert_eq!(resp.get("dropped").and_then(|v| v.as_usize()), Some(0));

        // Both servers must fully apply this round before the next
        // suggest, so selection state stays comparable.
        let want = ((round + 1) * n) as f64;
        wait_applied(&mut sc, want, "single server");
        wait_applied(&mut bc, want, "batch server");
    }

    // Final per-session statistics agree exactly.
    for e in &entries {
        let q = format!(
            "/v1/debug/session?client_id={}&app=clomp&device=maxn&policy={}&alpha={ALPHA}&beta={BETA}",
            e.client_id, e.policy
        );
        let (ss, sv) = sc.get(&q).unwrap();
        let (bs, bv) = bc.get(&q).unwrap();
        assert_eq!(ss, 200, "{sv:?}");
        assert_eq!(bs, 200, "{bv:?}");
        assert_eq!(
            bv.get("arms"),
            sv.get("arms"),
            "{}: per-arm statistics diverged between servers",
            e.client_id
        );
        assert_eq!(bv.get("total_pulls"), sv.get("total_pulls"), "{}", e.client_id);
    }

    drop(sc);
    drop(bc);
    single_srv.shutdown().unwrap();
    batch_srv.shutdown().unwrap();
}
