//! Chaos-engine integration suite: boots the real serve stack with the
//! `[chaos]` layer armed and pins the hardening invariants the layer
//! exists to prove — no reward double-counted under duplicate delivery,
//! fleet merges idempotent under replayed pushes, trace cursors monotone
//! while faults fire, kill/rejoin converging to the unfaulted best arm,
//! and chaos-laden sim grids bit-identical at any thread count.
//!
//! Every probabilistic test draws its seed from `LASP_CHAOS_SEED` (CI's
//! randomized smoke) and bakes the seed into assertion messages so a
//! failure is reproducible with `LASP_CHAOS_SEED=<seed> cargo test`.

use lasp::apps::AppKind;
use lasp::chaos::ChaosConfig;
use lasp::device::PowerMode;
use lasp::serve::{start, HttpClient, ServeConfig, TransportKind};
use lasp::sim::{parse_events, Scenario, ScenarioGrid, SweepResult, SweepRunner};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// The seed every chaos test runs under: `LASP_CHAOS_SEED` when set (the
/// CI randomized smoke), the layer's default otherwise.
fn chaos_seed() -> u64 {
    std::env::var("LASP_CHAOS_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE)
}

fn chaos_cfg(seed: u64) -> ChaosConfig {
    ChaosConfig { seed, ..ChaosConfig::default() }
}

fn serve_cfg(chaos: ChaosConfig) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 1,
        checkpoint_dir: None,
        chaos: Some(chaos),
        ..ServeConfig::default()
    }
}

fn body(client: &str, extra: &[(&str, Json)]) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(client.to_string()));
    obj.insert("app".to_string(), Json::Str("clomp".to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    obj.insert("alpha".to_string(), Json::Num(1.0));
    obj.insert("beta".to_string(), Json::Num(0.0));
    for (k, v) in extra {
        obj.insert((*k).to_string(), v.clone());
    }
    Json::Obj(obj)
}

fn best_query(client: &str) -> String {
    format!("/v1/best?client_id={client}&app=clomp&device=maxn&alpha=1.0&beta=0.0")
}

fn wait_until<F: FnMut() -> bool>(mut cond: F, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    cond()
}

fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.trim().parse::<f64>().ok()))
        .unwrap_or(0.0)
}

fn metrics_text(client: &mut HttpClient) -> String {
    let (status, page) = client.get("/metrics").unwrap();
    assert_eq!(status, 200);
    page.as_str().unwrap_or_default().to_string()
}

/// Suggest + report one round for `client_id`; `seq` opts the report into
/// the idempotency window. Returns the suggested arm.
fn one_round(client: &mut HttpClient, client_id: &str, seq: Option<u64>) -> usize {
    let (status, resp) = client.post("/v1/suggest", &body(client_id, &[])).unwrap();
    assert_eq!(status, 200, "suggest failed: {resp:?}");
    let arm = resp.get("arm").and_then(Json::as_usize).unwrap();
    let mut extra = vec![
        ("arm", Json::Num(arm as f64)),
        ("time_s", Json::Num(1.0 + (arm % 7) as f64 * 0.1)),
        ("power_w", Json::Num(5.0)),
    ];
    if let Some(s) = seq {
        extra.push(("seq", Json::Num(s as f64)));
    }
    let (status, resp) = client.post("/v1/report", &body(client_id, &extra)).unwrap();
    assert_eq!(status, 202, "report not queued: {resp:?}");
    arm
}

fn total_pulls(client: &mut HttpClient, client_id: &str) -> f64 {
    let (status, b) = client.get(&best_query(client_id)).unwrap();
    assert_eq!(status, 200, "{b:?}");
    b.get("total_pulls").and_then(Json::as_f64).unwrap_or(0.0)
}

/// Duplicate delivery (the `batch_flush` chaos point redelivers every
/// report) must not double-count rewards — *when* the client carries a
/// `seq` number. A seq-less client genuinely double-counts, which is the
/// contrast proving the faults actually fired.
#[test]
fn duplicate_delivery_never_double_counts_sequenced_reports() {
    let seed = chaos_seed();
    let handle = start(serve_cfg(ChaosConfig { flush_duplicate: 1.0, ..chaos_cfg(seed) })).unwrap();
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    let rounds = 40u64;
    for i in 0..rounds {
        one_round(&mut client, "careful", Some(i));
        one_round(&mut client, "naive", None);
    }

    assert!(
        wait_until(
            || {
                total_pulls(&mut client, "careful") == rounds as f64
                    && total_pulls(&mut client, "naive") == 2.0 * rounds as f64
            },
            Duration::from_secs(15),
        ),
        "seed={seed}: careful={} (want {rounds}), naive={} (want {})",
        total_pulls(&mut client, "careful"),
        total_pulls(&mut client, "naive"),
        2 * rounds,
    );

    let m = metrics_text(&mut client);
    assert!(metric_value(&m, "lasp_serve_chaos_enabled") == 1.0, "{m}");
    assert!(metric_value(&m, "lasp_serve_chaos_injections_total") >= rounds as f64, "{m}");
    assert!(
        metric_value(&m, "lasp_serve_reports_deduped_total") >= rounds as f64,
        "seed={seed}: dedup counter missing the rejected duplicates: {m}"
    );
    handle.shutdown().unwrap();
}

/// A batch slammed into a capacity-1 shard queue with the chaos layer
/// redelivering every flush: entries hitting the full queue must drop
/// and count INDIVIDUALLY (never fail the whole batch), the response's
/// per-entry statuses must reconcile exactly with the drop counters, and
/// every queued entry must apply exactly once despite duplicate
/// delivery. Regression test for the all-or-nothing enqueue bug where
/// one full queue 503'd every entry in the batch.
#[test]
fn batch_entries_against_a_full_queue_drop_and_count_individually() {
    let seed = chaos_seed();
    // Pinned to the blocking transport: bounded shard queues (and their
    // drop/backpressure semantics) are a shared-plane property. The
    // routed plane applies reports on their owning event loop and never
    // queues, so there is nothing to saturate there.
    let handle = start(ServeConfig {
        queue_cap: 1,
        transport: TransportKind::Blocking,
        ..serve_cfg(ChaosConfig { flush_duplicate: 1.0, ..chaos_cfg(seed) })
    })
    .unwrap();
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    // One full-cap batch (256 entries, the documented limit) for a single
    // session, distinct seqs: the handler's try_send loop outruns the
    // cap-1 updater by orders of magnitude, so most entries must shed.
    let n = 256usize;
    let entries: Vec<Json> = (0..n)
        .map(|seq| {
            let arm = seq % 5;
            body(
                "flood",
                &[
                    ("arm", Json::Num(arm as f64)),
                    ("time_s", Json::Num(1.0 + arm as f64 * 0.1)),
                    ("power_w", Json::Num(5.0)),
                    ("seq", Json::Num(seq as f64)),
                ],
            )
        })
        .collect();
    let mut batch = BTreeMap::new();
    batch.insert("entries".to_string(), Json::Arr(entries));
    let (status, resp) = client.post("/v1/report/batch", &Json::Obj(batch)).unwrap();
    assert_eq!(status, 202, "seed={seed}: a full queue must degrade entries, not the batch");
    let queued = resp.get("queued").and_then(Json::as_usize).unwrap();
    let dropped = resp.get("dropped").and_then(Json::as_usize).unwrap();
    assert_eq!(queued + dropped, n, "seed={seed}: {resp:?}");
    assert!(queued >= 1, "seed={seed}: the first entry had a cap-1 queue all to itself");
    assert!(dropped >= 1, "seed={seed}: 256 sends can't fit a cap-1 queue");
    let results = resp.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(results.len(), n);
    let by_status = |want: &str| {
        results
            .iter()
            .filter(|r| r.get("status").and_then(Json::as_str) == Some(want))
            .count()
    };
    assert_eq!(by_status("queued"), queued, "seed={seed}: {resp:?}");
    assert_eq!(by_status("dropped"), dropped, "seed={seed}: {resp:?}");

    // The drop counters reconcile exactly with the response…
    let m = metrics_text(&mut client);
    assert_eq!(metric_value(&m, "lasp_serve_reports_dropped_total"), dropped as f64, "{m}");
    assert_eq!(metric_value(&m, "lasp_serve_queue_backpressure_total"), dropped as f64, "{m}");
    assert_eq!(metric_value(&m, "lasp_serve_reports_enqueued_total"), queued as f64, "{m}");

    // …and every queued entry applies exactly once: the chaos layer
    // redelivers each flush, so each queued seq shows up once in
    // applied and once in deduped, and the session's pull count equals
    // the queued count — a dropped entry must never half-apply.
    assert!(
        wait_until(
            || {
                let m = metrics_text(&mut client);
                metric_value(&m, "lasp_serve_reports_applied_total") == queued as f64
                    && metric_value(&m, "lasp_serve_reports_deduped_total") == queued as f64
            },
            Duration::from_secs(15),
        ),
        "seed={seed}: queued entries never settled: {}",
        metrics_text(&mut client)
    );
    assert_eq!(total_pulls(&mut client, "flood"), queued as f64, "seed={seed}");
    let m = metrics_text(&mut client);
    assert!(
        metric_value(&m, "lasp_serve_chaos_injections_total") >= queued as f64,
        "seed={seed}: batch flush redeliveries missing from the injection counter: {m}"
    );
    handle.shutdown().unwrap();
}

/// A fleet push replayed verbatim (a retrying peer, a duplicated packet)
/// merges idempotently: three identical pushes leave exactly one copy of
/// the evidence, end to end through a pull.
#[test]
fn replayed_fleet_pushes_merge_idempotently() {
    let handle = start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 1,
        checkpoint_dir: None,
        node_id: Some("solo".to_string()),
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    let arr = |v: Vec<f64>| Json::Arr(v.into_iter().map(Json::Num).collect());
    let mut snap = BTreeMap::new();
    snap.insert("app".to_string(), Json::Str("clomp".to_string()));
    snap.insert("device".to_string(), Json::Str("maxn".to_string()));
    snap.insert("policy".to_string(), Json::Str("ucb".to_string()));
    snap.insert("age_s".to_string(), Json::Num(0.0));
    snap.insert("arms".to_string(), arr(vec![7.0]));
    snap.insert("counts".to_string(), arr(vec![40.0]));
    snap.insert("tau_sum".to_string(), arr(vec![12.0]));
    snap.insert("rho_sum".to_string(), arr(vec![200.0]));
    let mut push = BTreeMap::new();
    push.insert("node_id".to_string(), Json::Str("replayer".to_string()));
    push.insert("snapshots".to_string(), Json::Arr(vec![Json::Obj(snap)]));
    let push = Json::Obj(push);

    for i in 0..3 {
        let (status, resp) = client.post("/v1/sync/push", &push).unwrap();
        assert_eq!(status, 200, "push {i}: {resp:?}");
        assert_eq!(resp.get("nodes").and_then(Json::as_usize), Some(1), "push {i} not idempotent");
    }

    let mut pull = BTreeMap::new();
    pull.insert("node_id".to_string(), Json::Str("reader".to_string()));
    let (status, resp) = client.post("/v1/sync/pull", &Json::Obj(pull)).unwrap();
    assert_eq!(status, 200);
    let snaps = resp.get("snapshots").and_then(Json::as_arr).unwrap();
    assert_eq!(snaps.len(), 1, "{resp:?}");
    let c0 = snaps[0].get("counts").and_then(Json::as_arr).unwrap()[0].as_f64().unwrap();
    assert!((c0 - 40.0).abs() < 1.0, "replayed push double-counted: {c0}");
    handle.shutdown().unwrap();
}

/// While handler faults fire, `/v1/trace` cursors stay strictly monotone,
/// every injection surfaces as a `chaos` event naming its fault point,
/// and the degraded-mode `fleet_state` field is present.
#[test]
fn trace_cursors_stay_monotone_while_faults_fire() {
    let seed = chaos_seed();
    let handle = start(serve_cfg(ChaosConfig {
        handler_error: 0.3,
        handler_delay: 0.1,
        handler_delay_ms: 1,
        ..chaos_cfg(seed)
    }))
    .unwrap();
    let addr = handle.addr().to_string();
    let mut traffic = HttpClient::connect(&addr).unwrap();
    let mut probe = HttpClient::connect(&addr).unwrap();

    // The handler fault point fires before routing, so even probe reads
    // can draw an injected 503 — retry until one gets through (P(40
    // consecutive injections at p=0.4) ≈ 1e-16, for any seed).
    fn fetch_ok(probe: &mut HttpClient, addr: &str, path: &str, seed: u64) -> Json {
        for _ in 0..40 {
            match probe.get(path) {
                Ok((200, page)) => return page,
                Ok((503, _)) => {}
                Ok((status, resp)) => {
                    panic!("seed={seed}: unexpected status {status} for {path}: {resp:?}")
                }
                Err(_) => *probe = HttpClient::connect(addr).unwrap(),
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        panic!("seed={seed}: 40 consecutive injected faults on {path}");
    }

    let (mut cursor, mut faulted, mut chaos_events, mut handler_points) = (0u64, 0u32, 0u32, 0u32);
    for i in 0..200 {
        // An injected fault may cost us the connection; that is the point.
        match traffic.post("/v1/suggest", &body("storm", &[])) {
            Ok((200, _)) => {}
            Ok((503, _)) => faulted += 1,
            Ok((status, resp)) => panic!("seed={seed}: unexpected status {status}: {resp:?}"),
            Err(_) => {
                faulted += 1;
                traffic = HttpClient::connect(&addr).unwrap();
            }
        }
        if i % 20 != 19 {
            continue;
        }
        let page = fetch_ok(&mut probe, &addr, &format!("/v1/trace?since={cursor}"), seed);
        let next = page.get("next_since").and_then(Json::as_f64).unwrap() as u64;
        assert!(next >= cursor, "seed={seed}: cursor went backwards {cursor} -> {next}");
        assert!(
            page.get("fleet_state").and_then(Json::as_str).is_some(),
            "seed={seed}: trace page lost the degraded-mode field: {page:?}"
        );
        let events = page.get("events").and_then(Json::as_arr).unwrap();
        let mut prev = None;
        for e in events {
            let seq = e.get("seq").and_then(Json::as_f64).unwrap() as u64;
            assert!(seq >= cursor, "seed={seed}: drained event below the cursor");
            assert!(prev.map_or(true, |p| seq > p), "seed={seed}: seq not strictly monotone");
            prev = Some(seq);
            if e.get("kind").and_then(Json::as_str) == Some("chaos") {
                chaos_events += 1;
                if e.get("point").and_then(Json::as_str) == Some("handler") {
                    handler_points += 1;
                }
            }
        }
        cursor = next;
    }

    // P(zero injections over 200 requests at p≥0.3) < 1e-30: any seed
    // must have produced faults, and every fault must have left a trace.
    assert!(faulted > 0, "seed={seed}: chaos layer never injected");
    assert!(chaos_events > 0, "seed={seed}: injections missing from the flight recorder");
    assert!(handler_points > 0, "seed={seed}: chaos events lost their fault-point name");
    let m = fetch_ok(&mut probe, &addr, "/metrics", seed);
    let m = m.as_str().unwrap_or_default();
    assert!(
        metric_value(m, "lasp_serve_chaos_injections_total") >= faulted as f64,
        "seed={seed}: {m}"
    );
    handle.shutdown().unwrap();
}

/// A node killed mid-sweep (its reports lost, its budget burning) rejoins
/// and still converges to the best arm an unfaulted run finds, within a
/// bounded extra-rounds budget: the kill window plus slack.
#[test]
fn kill_and_rejoin_converges_to_the_unfaulted_best_arm() {
    let seed = chaos_seed();
    let baseline = vec![Scenario::lasp(AppKind::Clomp, PowerMode::Maxn, 600, seed)];
    let unfaulted = SweepRunner::new(2).run(&baseline).unwrap();

    // Kill at 150 until 450: 300 decisions burned, budget 600+300+50.
    let faulted_cells = vec![Scenario::lasp(AppKind::Clomp, PowerMode::Maxn, 950, seed)
        .with_events(parse_events("kill@150=450").unwrap())
        .recording_trace()];
    let faulted = SweepRunner::new(2).run(&faulted_cells).unwrap();

    assert_eq!(faulted[0].evaluations, 950, "kill window must still burn budget");
    assert_eq!(
        faulted[0].trace.as_ref().map(Vec::len),
        Some(950 - 300),
        "seed={seed}: decisions inside the kill window should not exist"
    );
    assert_eq!(
        faulted[0].best_index, unfaulted[0].best_index,
        "seed={seed}: kill/rejoin diverged from the unfaulted best arm"
    );
}

/// A scenario grid with every chaos schedule armed through the TOML DSL
/// replays bit-identically at any sweep thread count — the determinism
/// contract that makes a chaotic run debuggable.
#[test]
fn chaos_grids_replay_bit_identically_at_any_thread_count() {
    let seed = chaos_seed();
    let mut grid = ScenarioGrid::from_toml_str(
        "[sim]\n\
         events = \"churn@50=0.2, dup@150=0.3, zipf@250=1.1, delay@350=3, kill@450=520\"\n",
    )
    .unwrap();
    grid.iterations = 600;
    grid.seeds = vec![seed, seed ^ 0x5DEECE66D];
    grid.record_trace = true;
    let cells = grid.cells();

    let jsons: Vec<String> = [1usize, 4, 1]
        .iter()
        .map(|&threads| {
            let outcomes = SweepRunner::new(threads).run(&cells).unwrap();
            SweepResult { cells: cells.clone(), outcomes }.to_json()
        })
        .collect();
    assert_eq!(jsons[0], jsons[1], "seed={seed}: chaos grid diverged between 1 and 4 threads");
    assert_eq!(jsons[0], jsons[2], "seed={seed}: chaos grid is not re-runnable");
}

/// Injected fleet-sync failures drive the node into the explicit backoff
/// state (visible in `/metrics`) while the data plane keeps serving.
#[test]
fn injected_fleet_failures_enter_backoff_and_keep_serving() {
    let seed = chaos_seed();
    let leader = start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 1,
        checkpoint_dir: None,
        ..ServeConfig::default()
    })
    .unwrap();
    let follower = start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 1,
        checkpoint_dir: None,
        leader: Some(leader.addr().to_string()),
        node_id: Some("chaotic".to_string()),
        sync_every: Duration::from_millis(100),
        chaos: Some(ChaosConfig { fleet_fail: 1.0, ..chaos_cfg(seed) }),
        ..ServeConfig::default()
    })
    .unwrap();
    let follower_addr = follower.addr().to_string();
    let mut probe = HttpClient::connect(&follower_addr).unwrap();

    assert!(
        wait_until(
            || metric_value(&metrics_text(&mut probe), "lasp_serve_fleet_sync_state") == 2.0,
            Duration::from_secs(20),
        ),
        "seed={seed}: follower never entered backoff: {}",
        metrics_text(&mut probe)
    );
    let m = metrics_text(&mut probe);
    assert!(metric_value(&m, "lasp_serve_chaos_injections_total") >= 1.0, "seed={seed}: {m}");

    // Degraded mode still serves the data plane.
    let mut client = HttpClient::connect(&follower_addr).unwrap();
    for _ in 0..10 {
        one_round(&mut client, "degraded", None);
    }
    let (status, health) = client.get("/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("ok"), Some(&Json::Bool(true)));

    follower.shutdown().unwrap();
    leader.shutdown().unwrap();
}

/// Checkpoint write failures are retried, counted, and never take the
/// serving plane down; the last-good file survives (pinned at the unit
/// level in `serve/checkpoint.rs` — this is the end-to-end half).
#[test]
fn injected_checkpoint_failures_are_counted_and_survivable() {
    let seed = chaos_seed();
    let dir = std::env::temp_dir().join(format!("lasp-chaos-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let handle = start(ServeConfig {
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: Duration::from_secs(3600),
        ..serve_cfg(ChaosConfig { checkpoint_fail: 1.0, ..chaos_cfg(seed) })
    })
    .unwrap();
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();
    one_round(&mut client, "ckpt", None);

    // Every write attempt fails: the snapshot errors after its retries…
    let (status, resp) = client.post("/v1/checkpoint", &Json::Obj(BTreeMap::new())).unwrap();
    assert_eq!(status, 500, "seed={seed}: {resp:?}");
    let m = metrics_text(&mut client);
    assert!(
        metric_value(&m, "lasp_serve_checkpoint_failures_total") >= 1.0,
        "seed={seed}: {m}"
    );

    // …and the node shrugs it off.
    for _ in 0..5 {
        one_round(&mut client, "ckpt", None);
    }
    let (status, health) = client.get("/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("ok"), Some(&Json::Bool(true)));

    handle.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The `--chaos` config surface rejects malformed files and sections with
/// actionable errors instead of arming a half-configured layer.
#[test]
fn chaos_config_rejects_malformed_input() {
    assert!(ChaosConfig::from_toml_str("[serve]\nworkers = 2\n").is_err(), "missing section");
    assert!(ChaosConfig::from_toml_str("[chaos]\nhandler_error = 1.5\n").is_err());
    assert!(ChaosConfig::from_toml_str("[chaos]\naccept_drop = -0.1\n").is_err());
    assert!(ChaosConfig::from_toml_str("[chaos]\nhandler_delay_ms = 99999\n").is_err());
    let cfg = ChaosConfig::from_toml_str("[chaos]\nseed = 7\nflush_duplicate = 0.25\n").unwrap();
    assert_eq!(cfg.seed, 7);
    assert_eq!(cfg.flush_duplicate, 0.25);
    assert!(
        ChaosConfig::from_file(std::path::Path::new("/nonexistent/chaos.toml")).is_err(),
        "missing file must error cleanly"
    );
}
