//! CLI smoke tests: run the `lasp` binary end to end through its
//! subcommands (config file parsing, tuning, tables).

use std::process::Command;

fn lasp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_lasp"))
}

#[test]
fn help_lists_commands() {
    let out = lasp_bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in [
        "tune", "fleet", "serve", "loadgen", "compare", "experiment", "spaces", "devices",
    ] {
        assert!(text.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn usage_covers_every_flag() {
    // Anti-drift: every `--flag` the dispatcher actually reads
    // (`flags.get("…")` / `flags.has("…")` in main.rs) must appear in the
    // help output, so the usage text cannot rot away from the flag set.
    let src = include_str!("../src/main.rs");
    let out = lasp_bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let usage = String::from_utf8_lossy(&out.stdout);
    let mut flags = std::collections::BTreeSet::new();
    for pat in [".get(\"", ".has(\""] {
        let mut pos = 0;
        while let Some(i) = src[pos..].find(pat) {
            let start = pos + i + pat.len();
            let Some(end) = src[start..].find('"') else { break };
            let name = &src[start..start + end];
            pos = start + end;
            if !name.is_empty()
                && name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-')
            {
                flags.insert(name.to_string());
            }
        }
    }
    assert!(flags.len() >= 25, "flag extraction broke: found only {flags:?}");
    for f in &flags {
        assert!(usage.contains(&format!("--{f}")), "usage text missing --{f}");
    }
    // And the serve fleet-sync flags exist at all (tentpole surface).
    for f in ["leader", "node-id", "sync-secs", "fleet-retain", "half-life-secs"] {
        assert!(flags.contains(f), "main.rs no longer reads --{f}");
    }
}

#[test]
fn no_args_prints_usage() {
    let out = lasp_bin().output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = lasp_bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown command"), "{err}");
    // Not an opaque error: the full usage text rides along.
    assert!(err.contains("USAGE"), "{err}");
    assert!(err.contains("serve"), "{err}");
}

#[test]
fn devices_prints_table1() {
    let out = lasp_bin().arg("devices").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("MAXN") && text.contains("5W"));
    assert!(text.contains("1479"));
}

#[test]
fn spaces_prints_table2() {
    let out = lasp_bin().arg("spaces").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["kripke", "92160", "partsPerThread", "strong_threshold"] {
        assert!(text.contains(needle), "missing '{needle}'");
    }
}

#[test]
fn tune_runs_and_validates() {
    let out = lasp_bin()
        .args([
            "tune",
            "--app",
            "clomp",
            "--iters",
            "200",
            "--alpha",
            "1.0",
            "--beta",
            "0.0",
            "--seed",
            "3",
            "--hf-validate",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("tuned configuration"));
    assert!(text.contains("HF validation"));
}

#[test]
fn tune_with_config_file_and_override() {
    let dir = std::env::temp_dir().join(format!("lasp-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.toml");
    std::fs::write(
        &cfg,
        "[tune]\napp = \"lulesh\"\niterations = 150\nalpha = 1.0\nbeta = 0.0\n",
    )
    .unwrap();
    let out = lasp_bin()
        .args(["tune", "--config", cfg.to_str().unwrap(), "--seed", "9"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("app=lulesh"), "{text}");
    assert!(text.contains("iters=150"), "{text}");
}

#[test]
fn invalid_flags_rejected() {
    let out = lasp_bin().args(["tune", "--alpha", "7"]).output().unwrap();
    assert!(!out.status.success());
    let out = lasp_bin().args(["tune", "--app", "doom"]).output().unwrap();
    assert!(!out.status.success());
    let out = lasp_bin().args(["tune", "--iters"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn checkpoint_save_and_warm_start() {
    let dir = std::env::temp_dir().join(format!("lasp-cli-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("clomp.json");
    let out = lasp_bin()
        .args(["tune", "--app", "clomp", "--iters", "150", "--save-state", ckpt.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(ckpt.exists());

    let out = lasp_bin()
        .args(["tune", "--app", "clomp", "--iters", "60", "--load-state", ckpt.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("warm start"));

    // App mismatch must be rejected.
    let out = lasp_bin()
        .args(["tune", "--app", "kripke", "--load-state", ckpt.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn serve_rejects_non_multiple_shards_per_loop() {
    // The routed data plane maps shard s to event loop s % n_loops;
    // a shard count that isn't a multiple of the loop count would give
    // some loops more shards than others. That must be a clear CLI
    // error naming both flags, not a silently unbalanced ownership map.
    let out = lasp_bin()
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--transport",
            "reactor",
            "--shards",
            "6",
            "--event-loops",
            "4",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "non-multiple topology must be rejected");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--shards"), "error must name --shards: {err}");
    assert!(err.contains("--event-loops"), "error must name --event-loops: {err}");
    assert!(err.contains("multiple"), "error must explain the constraint: {err}");
}

#[test]
fn serve_defaults_shards_to_event_loop_count() {
    // With --shards unset (0 = auto) the shard count follows the
    // event-loop count, so every loop owns exactly one shard. The
    // banner prints the *resolved* topology; read it and kill the
    // server.
    use std::io::BufRead;
    let mut child = lasp_bin()
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--transport",
            "reactor",
            "--event-loops",
            "2",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut banner = String::new();
    for line in std::io::BufReader::new(stdout).lines() {
        let line = line.unwrap_or_default();
        if line.contains("# lasp serve:") {
            banner = line;
            break;
        }
    }
    let _ = child.kill();
    let _ = child.wait();
    assert!(
        banner.contains("threads=2 shards=2"),
        "banner should show shards derived from event loops: {banner:?}"
    );
}

#[test]
fn experiment_table2_runs() {
    let out = lasp_bin()
        .args(["experiment", "--name", "table2"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("shape OK"));
}

#[test]
fn experiment_fig3_quick_runs() {
    let out = lasp_bin()
        .args(["experiment", "--name", "fig3", "--quick"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("[shape OK] fig3"));
}
