//! Differential tests: the PJRT artifact path must agree with the pure-rust
//! scalar math across many random states — the core cross-layer correctness
//! guarantee of the three-layer architecture. All tests no-op (pass) when
//! artifacts are absent; `make artifacts` builds them.

use lasp::bandit::{ArmStats, ScalarBackend, ScoreBackend, Scratch};
use lasp::runtime::{Engine, EngineHandle};
use lasp::util::Rng;

fn engine() -> Option<Engine> {
    let dir = lasp::runtime::find_artifacts_dir()?;
    Some(Engine::load(&dir).expect("engine load"))
}

fn random_state(k: usize, pulls: usize, rng: &mut Rng) -> ArmStats {
    let mut s = ArmStats::new(k);
    for _ in 0..pulls {
        s.observe(rng.below(k), rng.range(0.05, 8.0), rng.range(1.0, 11.0));
    }
    s
}

#[test]
fn lasp_step_agrees_across_backends_many_states() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(99);
    for trial in 0..40 {
        let (app, k) = [("lulesh", 128), ("kripke", 216), ("clomp", 125)][trial % 3];
        let pulls = 1 + rng.below(3000);
        let state = random_state(k, pulls, &mut rng);
        let (alpha, beta) = (rng.uniform(), rng.uniform());
        let c = rng.range(0.05, 1.0);

        let tau: Vec<f32> = state.tau_sum().iter().map(|&v| v as f32).collect();
        let rho: Vec<f32> = state.rho_sum().iter().map(|&v| v as f32).collect();
        let cnt: Vec<f32> = state.counts().iter().map(|&v| v as f32).collect();
        let pjrt = e
            .lasp_step(app, &tau, &rho, &cnt, state.t() as f32, alpha as f32, beta as f32, c as f32)
            .unwrap();
        let mut scratch = Scratch::new();
        let scalar = ScalarBackend.lasp_step(&state, alpha, beta, c, &mut scratch).unwrap();

        // Rewards agree to f32 tolerance.
        for (i, (a, b)) in pjrt.rewards.iter().zip(&scratch.rewards).enumerate() {
            assert!(
                (*a as f64 - b).abs() < 5e-4,
                "trial {trial} {app} arm {i}: pjrt {a} vs scalar {b}"
            );
        }
        // Selection agrees, or is an f32-level tie.
        if pjrt.best != scalar.best {
            assert!(
                (pjrt.score - scalar.score).abs() < 5e-4,
                "trial {trial} {app}: pjrt #{} ({}) vs scalar #{} ({})",
                pjrt.best,
                pjrt.score,
                scalar.best,
                scalar.score
            );
        }
    }
}

#[test]
fn episode_artifact_matches_step_by_step_scalar_replay() {
    let Some(mut e) = engine() else { return };
    let k = 216;
    let mut rng = Rng::new(7);
    let rewards_f64: Vec<f64> = (0..k).map(|_| rng.uniform()).collect();
    let rewards: Vec<f32> = rewards_f64.iter().map(|&v| v as f32).collect();
    let (counts, trace) = e
        .ucb_episode("kripke", 500, &rewards, &vec![0.0; k], 1.0, 1.0)
        .unwrap();

    // Scalar replay of the same mean-field episode.
    let mut c = vec![0.0f64; k];
    let mut t = 1.0f64;
    for (step, &sel) in trace.iter().enumerate() {
        let scores = lasp::bandit::reward::ucb_scores(&rewards_f64, &c, t, 1.0);
        let best = lasp::util::stats::argmax(&scores);
        // f32 ties can flip the argmax; accept scores equal to 1e-5.
        assert!(
            (scores[best] - scores[sel as usize]).abs() < 1e-5,
            "step {step}: scalar #{best} vs artifact #{sel}"
        );
        c[sel as usize] += 1.0;
        t += 1.0;
    }
    let sum: f32 = counts.iter().sum();
    assert_eq!(sum, 500.0);
}

#[test]
fn reward_norm_artifact_matches_scalar() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(17);
    let k = 125;
    let state = random_state(k, 700, &mut rng);
    let tau: Vec<f32> = state.tau_sum().iter().map(|&v| v as f32).collect();
    let rho: Vec<f32> = state.rho_sum().iter().map(|&v| v as f32).collect();
    let cnt: Vec<f32> = state.counts().iter().map(|&v| v as f32).collect();
    let rewards = e.reward_norm("clomp", &tau, &rho, &cnt, 0.6, 0.4).unwrap();
    let (mt, mr) = state.filled_means();
    let want = lasp::bandit::reward::weighted_rewards(&mt, &mr, 0.6, 0.4);
    for (i, (a, b)) in rewards.iter().zip(&want).enumerate() {
        assert!((*a as f64 - b).abs() < 5e-4, "arm {i}: {a} vs {b}");
    }
}

#[test]
fn handle_and_direct_engine_agree() {
    let Some(dir) = lasp::runtime::find_artifacts_dir() else { return };
    let mut direct = Engine::load(&dir).unwrap();
    let handle = EngineHandle::spawn(dir).unwrap();
    let mut rng = Rng::new(23);
    let k = 128;
    let state = random_state(k, 500, &mut rng);
    let tau: Vec<f32> = state.tau_sum().iter().map(|&v| v as f32).collect();
    let rho: Vec<f32> = state.rho_sum().iter().map(|&v| v as f32).collect();
    let cnt: Vec<f32> = state.counts().iter().map(|&v| v as f32).collect();
    let a = direct
        .lasp_step("lulesh", &tau, &rho, &cnt, 501.0, 0.8, 0.2, 0.25)
        .unwrap();
    let b = handle
        .lasp_step("lulesh", tau, rho, cnt, 501.0, 0.8, 0.2, 0.25)
        .unwrap();
    assert_eq!(a.best, b.best);
    assert_eq!(a.rewards, b.rewards);
}

#[test]
fn gp_artifact_agrees_with_rust_gp() {
    let Some(mut e) = engine() else { return };
    let (n, m, d) = e.gp_shape().unwrap();
    let mut rng = Rng::new(31);
    let n_real = 20;
    // Random observed points in [0,1]^d and rewards.
    let mut x = vec![0f32; n * d];
    let mut y = vec![0f32; n];
    let mut mask = vec![0f32; n];
    let mut x_rust: Vec<Vec<f64>> = vec![];
    let mut y_rust: Vec<f64> = vec![];
    for i in 0..n_real {
        let row: Vec<f64> = (0..d).map(|_| rng.uniform()).collect();
        for (c, &v) in row.iter().enumerate() {
            x[i * d + c] = v as f32;
        }
        let val = rng.uniform();
        y[i] = val as f32;
        mask[i] = 1.0;
        x_rust.push(row);
        y_rust.push(val);
    }
    let mut xs = vec![0f32; m * d];
    let mut queries: Vec<Vec<f64>> = vec![];
    for r in 0..m {
        let row: Vec<f64> = (0..d).map(|_| rng.uniform()).collect();
        for (c, &v) in row.iter().enumerate() {
            xs[r * d + c] = v as f32;
        }
        queries.push(row);
    }
    let (mean, var, _, _) = e.gp_propose(&x, &y, &mask, &xs, 0.5, 1e-2, 0.5).unwrap();

    let mut gp = lasp::baselines::GpSurrogate::new(0.5, 1e-2);
    gp.fit(x_rust, y_rust).unwrap();
    for i in (0..m).step_by(37) {
        let (mu, v) = gp.predict(&queries[i]);
        assert!(
            (mean[i] as f64 - mu).abs() < 2e-2,
            "mean[{i}]: pjrt {} vs rust {mu}",
            mean[i]
        );
        assert!(
            (var[i] as f64 - v).abs() < 2e-2,
            "var[{i}]: pjrt {} vs rust {v}",
            var[i]
        );
    }
}
