//! Documentation anti-drift tests: every route string registered in the
//! serve router must be documented in `docs/API.md`, the documented
//! status codes must cover the transport's error set, and every relative
//! markdown link in README/DESIGN/docs must resolve to a real file.

use std::collections::BTreeSet;

const SERVICE_SRC: &str = include_str!("../src/serve/service.rs");
const API_MD: &str = include_str!("../../docs/API.md");
const README_MD: &str = include_str!("../../README.md");
const DESIGN_MD: &str = include_str!("../../DESIGN.md");

/// Extract route string literals (`"/v1/..."`, `"/healthz"`,
/// `"/metrics"`) from the router source.
fn route_literals(src: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let bytes = src.as_bytes();
    let mut i = 0;
    while i + 1 < bytes.len() {
        if bytes[i] == b'"' && bytes[i + 1] == b'/' {
            if let Some(end) = src[i + 1..].find('"') {
                let lit = &src[i + 1..i + 1 + end];
                if lit.starts_with("/v1/") || lit == "/healthz" || lit == "/metrics" {
                    out.insert(lit.to_string());
                }
                i += end + 2;
                continue;
            }
        }
        i += 1;
    }
    out
}

#[test]
fn api_doc_covers_every_registered_route() {
    let routes = route_literals(SERVICE_SRC);
    // The router registers (at least) the eight known endpoints; if this
    // shrinks, the extraction logic broke, not the API.
    for expected in [
        "/v1/suggest",
        "/v1/report",
        "/v1/suggest/batch",
        "/v1/report/batch",
        "/v1/best",
        "/v1/checkpoint",
        "/v1/sync/push",
        "/v1/sync/pull",
        "/v1/trace",
        "/v1/debug/session",
        "/healthz",
        "/metrics",
    ] {
        assert!(
            routes.contains(expected),
            "route extraction lost {expected}: {routes:?}"
        );
    }
    for route in &routes {
        assert!(
            API_MD.contains(&format!("`{route}`")),
            "docs/API.md does not document route {route}"
        );
    }
}

#[test]
fn api_doc_covers_transport_status_codes() {
    // Every status the zero-alloc parser and handlers can emit.
    for code in ["200", "202", "400", "404", "405", "408", "413", "431", "500", "501", "503"] {
        assert!(
            API_MD.contains(code),
            "docs/API.md does not mention status code {code}"
        );
    }
    assert!(
        API_MD.to_lowercase().contains("keep-alive"),
        "docs/API.md must describe keep-alive semantics"
    );
}

/// Walk `](target)` markdown links and assert relative targets exist
/// (relative to the repo root, which is where `cargo test` runs).
fn assert_links_resolve(md: &str, label: &str) {
    let mut pos = 0;
    let mut checked = 0;
    while let Some(idx) = md[pos..].find("](") {
        let start = pos + idx + 2;
        let Some(close) = md[start..].find(')') else { break };
        let target = &md[start..start + close];
        pos = start + close;
        if target.is_empty()
            || target.starts_with("http://")
            || target.starts_with("https://")
            || target.starts_with('#')
            || target.starts_with("mailto:")
        {
            continue;
        }
        let path = target.split('#').next().unwrap_or(target);
        assert!(
            std::path::Path::new(path).exists(),
            "{label}: broken relative link '{target}'"
        );
        checked += 1;
    }
    let _ = checked;
}

#[test]
fn markdown_links_resolve() {
    assert_links_resolve(README_MD, "README.md");
    assert_links_resolve(DESIGN_MD, "DESIGN.md");
    assert_links_resolve(API_MD, "docs/API.md");
}

#[test]
fn design_documents_fleet_protocol_and_checkpoint_format() {
    for needle in [
        "Networked fleet sync",
        "/v1/sync/push",
        "/v1/sync/pull",
        "idempoten",
        "half_life",
        "Checkpoint file format",
        "sess-",
    ] {
        assert!(
            DESIGN_MD.contains(needle),
            "DESIGN.md missing '{needle}' (fleet protocol / checkpoint format section)"
        );
    }
}

#[test]
fn design_documents_bandit_core_architecture() {
    for needle in [
        "Bandit core",
        "ArmStats layout",
        "Scratch lifecycle",
        "Unified warm-start path",
        "total_pulls",
        "weighted_rewards_into",
        "policy_golden",
    ] {
        assert!(
            DESIGN_MD.contains(needle),
            "DESIGN.md missing '{needle}' (bandit-core architecture section)"
        );
    }
}

#[test]
fn design_documents_simulation_engine() {
    for needle in [
        "Simulation engine",
        "Episode model",
        "Determinism contract",
        "Scenario-file schema",
        "SweepRunner",
        "SearchStep",
        "PolicyStep",
        "lasp simulate",
        "events",
        "docs/scenarios/modeswitch-burst.toml",
        "BENCH_experiments.json",
    ] {
        assert!(
            DESIGN_MD.contains(needle),
            "DESIGN.md missing '{needle}' (simulation-engine section)"
        );
    }
    // The schema block documents every grid axis and every event action.
    for key in [
        "apps", "modes", "noise", "objectives", "strategies", "seeds", "iterations",
        "fidelity", "record_trace", "record_regret", "trace",
    ] {
        assert!(
            DESIGN_MD.contains(&format!("{key} = ")),
            "DESIGN.md scenario schema missing key '{key}'"
        );
    }
    for action in ["mode@", "noise@", "bus@", "clear@"] {
        assert!(
            DESIGN_MD.contains(action),
            "DESIGN.md scenario schema missing event action '{action}'"
        );
    }
    // README carries the quickstart for the same subcommand.
    assert!(
        README_MD.contains("lasp simulate"),
        "README.md missing the `lasp simulate` quickstart"
    );
    assert!(
        README_MD.contains("docs/scenarios/modeswitch-burst.toml"),
        "README.md must link the runnable example scenario"
    );
}

#[test]
fn design_documents_observability() {
    for needle in [
        "Observability",
        "Flight recorder",
        "LASPTRC1",
        "seqlock",
        "overwritten",
        "/v1/trace",
        "/v1/debug/session",
        "lasp trace",
        "--trace-file",
        "--record",
        "replay",
        "trace_overhead",
    ] {
        assert!(
            DESIGN_MD.contains(needle),
            "DESIGN.md missing '{needle}' (observability section)"
        );
    }
    // The event schema table names every event kind the recorder emits.
    for kind in [
        "req_start", "req_end", "suggest", "report_apply", "batch_flush", "fleet_push",
        "fleet_pull", "fleet_merge", "checkpoint", "session_create", "measure", "chaos",
        "conn_open", "conn_close",
    ] {
        assert!(
            DESIGN_MD.contains(kind),
            "DESIGN.md event schema missing kind '{kind}'"
        );
    }
}

#[test]
fn design_documents_failure_model_and_chaos_layer() {
    // §Failure model: fault points, degraded-mode states, idempotency
    // window semantics, and the chaos layer that exercises them.
    for needle in [
        "Failure model",
        "[chaos]",
        "--chaos",
        "batch_flush",
        "fleet_sync",
        "checkpoint_write",
        "standalone",
        "syncing",
        "backoff",
        "SeqWindow",
        "idempotency window",
        "lasp_serve_reports_dropped_total",
        "lasp_serve_checkpoint_failures_total",
        "LASP_CHAOS_SEED",
    ] {
        assert!(
            DESIGN_MD.contains(needle),
            "DESIGN.md missing '{needle}' (failure-model section)"
        );
    }
    // The scenario schema documents every adversarial event action.
    for action in ["churn@", "dup@", "zipf@", "delay@", "kill@"] {
        assert!(
            DESIGN_MD.contains(action),
            "DESIGN.md scenario schema missing chaos event action '{action}'"
        );
    }
    // The API reference documents the idempotency field and the
    // degraded-mode surfaces clients can observe.
    for needle in [
        "`seq`",
        "report queue full",
        "lasp_serve_fleet_sync_state",
        "fleet_state",
        "lasp_serve_chaos_injections_total",
        "lasp_serve_reports_deduped_total",
    ] {
        assert!(
            API_MD.contains(needle),
            "docs/API.md missing '{needle}' (failure-model surfaces)"
        );
    }
}

#[test]
fn design_documents_batched_scoring() {
    // §Batched scoring: shard grouping, the per-worker arena, and the
    // kernel vectorization/bit-stability contract.
    for needle in [
        "Batched scoring",
        "/v1/suggest/batch",
        "/v1/report/batch",
        "enqueue_group",
        "BatchArena",
        "select_traced_in",
        "select_batch",
        "ucb_scores_into",
        "batch_equivalence",
        "bit-identical",
    ] {
        assert!(
            DESIGN_MD.contains(needle),
            "DESIGN.md missing '{needle}' (batched-scoring section)"
        );
    }
    // The API reference documents both endpoints' semantics: the entry
    // cap, per-entry statuses, and the all-or-nothing validation rule.
    for needle in [
        "`/v1/suggest/batch`",
        "`/v1/report/batch`",
        "256 entries",
        "all-or-nothing",
        "\"dropped\"",
        "lasp_serve_batch_size",
    ] {
        assert!(
            API_MD.contains(needle),
            "docs/API.md missing '{needle}' (batch endpoint semantics)"
        );
    }
    // README carries the batched loadgen quickstart.
    assert!(
        README_MD.contains("--batch"),
        "README.md missing the loadgen --batch quickstart"
    );
}

#[test]
fn design_documents_event_driven_transport() {
    // §Event-driven transport: the per-connection state machine, the
    // poller abstraction, the timer wheel, and per-loop buffer ownership.
    for needle in [
        "Event-driven transport",
        "--event-loops",
        "Poller",
        "epoll",
        "poll(2)",
        "LASP_POLLER",
        "timer wheel",
        "EPOLLOUT",
        "slab",
        "generation",
        "round-robin",
        "Draining",
        "lasp_serve_event_loops",
        "lasp_serve_epoll_wakeups_total",
        "lasp_serve_conns_open",
        "lasp_serve_write_backpressure_total",
        "--transport blocking",
        "transport_differential",
    ] {
        assert!(
            DESIGN_MD.contains(needle),
            "DESIGN.md missing '{needle}' (event-driven transport section)"
        );
    }
    // The API reference explains the semantics shift: event loops size
    // the reactor, they do not bound concurrent connections the way
    // --workers bounded the blocking pool.
    for needle in [
        "--event-loops",
        "--transport",
        "lasp_serve_conns_open",
        "lasp_serve_write_backpressure_total",
    ] {
        assert!(
            API_MD.contains(needle),
            "docs/API.md missing '{needle}' (transport semantics)"
        );
    }
    // README carries the serve-flag quickstart and the open-loop
    // loadgen holder that drives the high-connection bench series.
    for needle in ["--event-loops", "--connections"] {
        assert!(
            README_MD.contains(needle),
            "README.md missing '{needle}' (transport quickstart)"
        );
    }
}

#[test]
fn design_documents_shared_nothing() {
    // §Shared-nothing data plane: the ownership map, the re-home vs
    // forward routing tradeoff, the snapshot (scatter-gather) protocol,
    // and the loop-stall failure semantics.
    for needle in [
        "Shared-nothing data plane",
        "owner(shard) = shard % L",
        "re-home",
        "mailbox",
        "scatter_gather",
        "key cache",
        "lasp-loop-<i>",
        "Loop-stall failure semantics",
        "partial",
        "lasp_serve_loop_owned_sessions",
        "lasp_serve_forwarded_requests_total",
        "lasp_serve_key_cache_hits_total",
        "--shards 0",
        "owned_shard_mut",
    ] {
        assert!(
            DESIGN_MD.contains(needle),
            "DESIGN.md missing '{needle}' (shared-nothing data plane section)"
        );
    }
    // The API reference documents the client-visible surfaces: routing
    // invisibility, routed report/batch acceptance semantics, and the
    // new telemetry.
    for needle in [
        "Shared-nothing data plane",
        "bit-identical across loop counts",
        "lasp_serve_loop_owned_sessions",
        "lasp_serve_forwarded_requests_total",
        "lasp_serve_key_cache_hits_total",
    ] {
        assert!(
            API_MD.contains(needle),
            "docs/API.md missing '{needle}' (shared-nothing surfaces)"
        );
    }
}

#[test]
fn api_doc_covers_every_policy_kind() {
    // The serve config parses these policy names; each must be documented.
    for policy in ["ucb", "swucb", "thompson", "epsilon", "subset"] {
        assert!(
            API_MD.contains(policy),
            "docs/API.md does not document policy '{policy}'"
        );
    }
}
