//! Two-node fleet-sync integration: a veteran node learns a scenario, a
//! follower pulls the fleet prior over real HTTP and warm-starts a fresh
//! session that reaches best-config parity in measurably fewer
//! suggest/report rounds than a cold-started node; killing the leader
//! mid-run leaves every node serving suggestions without errors.

use lasp::serve::{start, HttpClient, ServeConfig};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// The uniquely fastest clomp arm in this synthetic landscape.
const BEST_ARM: usize = 77;

/// Arm-determined measurement: stationary, unique minimum at [`BEST_ARM`].
fn fake_time(arm: usize) -> f64 {
    if arm == BEST_ARM {
        0.3
    } else {
        2.0 + (arm % 13) as f64 * 0.05
    }
}

fn cfg(leader: Option<String>, sync_ms: u64, node_id: &str) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        shards: 2,
        queue_cap: 1024,
        max_batch: 64,
        checkpoint_dir: None,
        leader,
        node_id: Some(node_id.to_string()),
        sync_every: Duration::from_millis(sync_ms),
        fleet_retain: 0.5,
        fleet_half_life: Duration::from_secs(600),
        ..ServeConfig::default()
    }
}

fn body(client: &str, extra: &[(&str, Json)]) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(client.to_string()));
    obj.insert("app".to_string(), Json::Str("clomp".to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    obj.insert("alpha".to_string(), Json::Num(1.0));
    obj.insert("beta".to_string(), Json::Num(0.0));
    for (k, v) in extra {
        obj.insert((*k).to_string(), v.clone());
    }
    Json::Obj(obj)
}

fn best_query(client: &str) -> String {
    format!("/v1/best?client_id={client}&app=clomp&device=maxn&alpha=1.0&beta=0.0")
}

fn wait_until<F: FnMut() -> bool>(mut cond: F, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    cond()
}

/// One suggest + evaluate + report round. Returns the suggested arm.
fn one_round(client: &mut HttpClient, client_id: &str) -> usize {
    let (status, resp) = client.post("/v1/suggest", &body(client_id, &[])).unwrap();
    assert_eq!(status, 200, "suggest failed: {resp:?}");
    let arm = resp.get("arm").and_then(Json::as_usize).unwrap();
    let (status, resp) = client
        .post(
            "/v1/report",
            &body(
                client_id,
                &[
                    ("arm", Json::Num(arm as f64)),
                    ("time_s", Json::Num(fake_time(arm))),
                    ("power_w", Json::Num(5.0)),
                ],
            ),
        )
        .unwrap();
    assert_eq!(status, 202, "report not queued: {resp:?}");
    arm
}

/// Rounds until `/v1/best` answers [`BEST_ARM`] (capped). The
/// convergence metric of the acceptance criterion.
fn rounds_to_parity(addr: &str, client_id: &str, cap: usize) -> usize {
    let mut client = HttpClient::connect(addr).unwrap();
    for round in 1..=cap {
        one_round(&mut client, client_id);
        let (status, b) = client.get(&best_query(client_id)).unwrap();
        assert_eq!(status, 200);
        if b.get("arm").and_then(Json::as_usize) == Some(BEST_ARM) {
            return round;
        }
    }
    cap
}

fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.trim().parse::<f64>().ok()))
        .unwrap_or(0.0)
}

fn metrics_text(client: &mut HttpClient) -> String {
    let (status, page) = client.get("/metrics").unwrap();
    assert_eq!(status, 200);
    page.as_str().unwrap_or_default().to_string()
}

#[test]
fn fleet_prior_warm_start_beats_cold_start_and_survives_leader_death() {
    // --- Leader: learn the scenario with a veteran client. ---
    let leader = start(cfg(None, 60_000, "leader")).unwrap();
    let leader_addr = leader.addr().to_string();
    let mut veteran = HttpClient::connect(&leader_addr).unwrap();
    let veteran_rounds = 300usize;
    for _ in 0..veteran_rounds {
        one_round(&mut veteran, "veteran");
    }
    // Wait for the async report plane to drain, then sanity-check that
    // the veteran actually converged on the designed optimum.
    assert!(
        wait_until(
            || {
                let (s, b) = veteran.get(&best_query("veteran")).unwrap();
                s == 200
                    && b.get("total_pulls").and_then(Json::as_f64)
                        == Some(veteran_rounds as f64)
            },
            Duration::from_secs(15)
        ),
        "veteran reports never fully applied"
    );
    let (_, b) = veteran.get(&best_query("veteran")).unwrap();
    assert_eq!(
        b.get("arm").and_then(Json::as_usize),
        Some(BEST_ARM),
        "veteran did not converge; landscape broken"
    );

    // --- Follower: sync against the leader, then serve a newcomer. ---
    let follower = start(cfg(Some(leader_addr.clone()), 200, "edge-b")).unwrap();
    let follower_addr = follower.addr().to_string();
    let mut fprobe = HttpClient::connect(&follower_addr).unwrap();
    assert!(
        wait_until(
            || {
                let m = metrics_text(&mut fprobe);
                metric_value(&m, "lasp_serve_fleet_pulls_total") >= 1.0
                    && metric_value(&m, "lasp_serve_fleet_prior_keys") >= 1.0
            },
            Duration::from_secs(20)
        ),
        "follower never completed a sync cycle"
    );
    let warm_rounds = rounds_to_parity(&follower_addr, "newcomer", 200);
    let m = metrics_text(&mut fprobe);
    assert!(
        metric_value(&m, "lasp_serve_fleet_warm_starts_total") >= 1.0,
        "newcomer session was not warm-started: {m}"
    );

    // --- Cold baseline: an isolated node, same traffic pattern. ---
    let cold = start(cfg(None, 60_000, "cold")).unwrap();
    let cold_addr = cold.addr().to_string();
    let cold_rounds = rounds_to_parity(&cold_addr, "newcomer", 200);

    // A cold 125-arm UCB session cannot even finish its init sweep before
    // round 125; the warm-started one answers the fleet optimum almost
    // immediately. "Measurably fewer" with wide safety margins:
    assert!(
        warm_rounds < cold_rounds,
        "warm start not faster: warm={warm_rounds} cold={cold_rounds}"
    );
    assert!(warm_rounds <= 40, "warm start too slow: {warm_rounds} rounds");
    assert!(cold_rounds >= 100, "cold baseline implausibly fast: {cold_rounds} rounds");

    // --- Kill the leader mid-run: everyone keeps serving. ---
    drop(veteran);
    leader.shutdown().unwrap();
    assert!(
        wait_until(
            || metric_value(
                &metrics_text(&mut fprobe),
                "lasp_serve_fleet_sync_errors_total"
            ) >= 1.0,
            Duration::from_secs(20)
        ),
        "follower never noticed the dead leader"
    );
    let mut fclient = HttpClient::connect(&follower_addr).unwrap();
    let mut cclient = HttpClient::connect(&cold_addr).unwrap();
    for _ in 0..20 {
        // one_round asserts 200/202 internally: suggest never degrades.
        one_round(&mut fclient, "after-death");
        one_round(&mut cclient, "after-death");
    }
    let (status, health) = fclient.get("/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("ok"), Some(&Json::Bool(true)));

    follower.shutdown().unwrap();
    cold.shutdown().unwrap();
}

#[test]
fn sync_endpoints_validate_and_pushes_are_idempotent() {
    let node = start(cfg(None, 60_000, "solo")).unwrap();
    let addr = node.addr().to_string();
    assert_eq!(node.node_id(), "solo");
    let mut client = HttpClient::connect(&addr).unwrap();

    // Malformed sync requests are 400s, never panics.
    let (status, _) = client.post("/v1/sync/push", &Json::Str("nope".into())).unwrap();
    assert_eq!(status, 400);
    let (status, _) = client.post("/v1/sync/push", &Json::Obj(BTreeMap::new())).unwrap();
    assert_eq!(status, 400, "missing node_id accepted");
    let (status, _) = client.post("/v1/sync/pull", &Json::Obj(BTreeMap::new())).unwrap();
    assert_eq!(status, 400, "missing node_id accepted");
    // Self-sync misconfiguration is refused loudly.
    let mut self_push = BTreeMap::new();
    self_push.insert("node_id".to_string(), Json::Str("solo".to_string()));
    self_push.insert("snapshots".to_string(), Json::Arr(vec![]));
    let (status, _) = client.post("/v1/sync/push", &Json::Obj(self_push)).unwrap();
    assert_eq!(status, 400, "self-push accepted");
    // Sync endpoints are POST-only.
    let (status, _) = client.get("/v1/sync/pull").unwrap();
    assert_eq!(status, 404);

    // A valid push: one clomp snapshot where arm 5 dominates.
    let snapshot = |arms: Vec<f64>, counts: Vec<f64>, tau: Vec<f64>, rho: Vec<f64>| {
        let arr = |v: Vec<f64>| Json::Arr(v.into_iter().map(Json::Num).collect());
        let mut o = BTreeMap::new();
        o.insert("app".to_string(), Json::Str("clomp".to_string()));
        o.insert("device".to_string(), Json::Str("maxn".to_string()));
        o.insert("policy".to_string(), Json::Str("ucb".to_string()));
        o.insert("age_s".to_string(), Json::Num(0.0));
        o.insert("arms".to_string(), arr(arms));
        o.insert("counts".to_string(), arr(counts));
        o.insert("tau_sum".to_string(), arr(tau));
        o.insert("rho_sum".to_string(), arr(rho));
        Json::Obj(o)
    };
    let push = |snaps: Vec<Json>| {
        let mut o = BTreeMap::new();
        o.insert("node_id".to_string(), Json::Str("peer-1".to_string()));
        o.insert("snapshots".to_string(), Json::Arr(snaps));
        Json::Obj(o)
    };
    let snap = snapshot(
        vec![5.0],
        vec![60.0],
        vec![18.0],  // mean time 0.3
        vec![300.0], // mean power 5.0
    );
    for _ in 0..3 {
        let (status, resp) = client.post("/v1/sync/push", &push(vec![snap.clone()])).unwrap();
        assert_eq!(status, 200, "{resp:?}");
        assert_eq!(resp.get("accepted").and_then(Json::as_usize), Some(1));
        assert_eq!(resp.get("nodes").and_then(Json::as_usize), Some(1), "push not idempotent");
    }

    // A malformed snapshot inside an otherwise valid push is rejected.
    let bad = snapshot(vec![5.0, 4.0], vec![1.0, 1.0], vec![1.0, 1.0], vec![1.0, 1.0]);
    let (status, _) = client.post("/v1/sync/push", &push(vec![bad])).unwrap();
    assert_eq!(status, 400, "unsorted arms accepted");

    // Pulling as another peer sees peer-1's evidence once (idempotency
    // end to end: three pushes, one copy).
    let mut pull = BTreeMap::new();
    pull.insert("node_id".to_string(), Json::Str("peer-2".to_string()));
    let (status, resp) = client.post("/v1/sync/pull", &Json::Obj(pull.clone())).unwrap();
    assert_eq!(status, 200);
    let snaps = resp.get("snapshots").and_then(Json::as_arr).unwrap();
    assert_eq!(snaps.len(), 1);
    let counts = snaps[0].get("counts").and_then(Json::as_arr).unwrap();
    let c0 = counts[0].as_f64().unwrap();
    assert!((c0 - 60.0).abs() < 1.0, "triple push double-counted: {c0}");

    // Pulling as peer-1 must not echo peer-1's own evidence back.
    pull.insert("node_id".to_string(), Json::Str("peer-1".to_string()));
    let (status, resp) = client.post("/v1/sync/pull", &Json::Obj(pull)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(
        resp.get("snapshots").and_then(Json::as_arr).map(|s| s.len()),
        Some(0),
        "pull echoed the requester's own snapshots"
    );

    // The push installed a warm-start prior on this node: a brand-new
    // session immediately answers the pushed optimum.
    let (status, _) = client.post("/v1/suggest", &body("fresh", &[])).unwrap();
    assert_eq!(status, 200);
    let (status, b) = client.get(&best_query("fresh")).unwrap();
    assert_eq!(status, 200);
    assert_eq!(b.get("arm").and_then(Json::as_usize), Some(5));
    let m = metrics_text(&mut client);
    assert!(metric_value(&m, "lasp_serve_fleet_warm_starts_total") >= 1.0, "{m}");
    assert!(metric_value(&m, "lasp_serve_fleet_push_snapshots_total") >= 3.0, "{m}");
    assert!(metric_value(&m, "lasp_serve_fleet_nodes") >= 1.0, "{m}");

    node.shutdown().unwrap();
}

#[test]
fn epsilon_policy_rides_the_sync_plane_round_trip() {
    // Satellite coverage for PolicyKind::Epsilon: an epsilon snapshot
    // pushed over the wire installs a prior that warm-starts a fresh
    // epsilon session, and that session's own measurements travel back
    // out through /v1/sync/pull (ε-greedy was invisible to both planes
    // before the unified core).
    let node = start(cfg(None, 60_000, "solo-eps")).unwrap();
    let addr = node.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    // Push a full-sweep epsilon snapshot where arm 5 dominates (every arm
    // pulled once so the warm start skips the init sweep).
    let arr = |v: Vec<f64>| Json::Arr(v.into_iter().map(Json::Num).collect());
    let arms: Vec<f64> = (0..125).map(|a| a as f64).collect();
    let counts: Vec<f64> = (0..125).map(|a| if a == 5 { 60.0 } else { 1.0 }).collect();
    let tau: Vec<f64> = (0..125)
        .map(|a| if a == 5 { 18.0 } else { 2.0 })
        .collect();
    let rho: Vec<f64> = counts.iter().map(|c| c * 5.0).collect();
    let mut snap = BTreeMap::new();
    snap.insert("app".to_string(), Json::Str("clomp".to_string()));
    snap.insert("device".to_string(), Json::Str("maxn".to_string()));
    snap.insert("policy".to_string(), Json::Str("epsilon".to_string()));
    snap.insert("age_s".to_string(), Json::Num(0.0));
    snap.insert("arms".to_string(), arr(arms));
    snap.insert("counts".to_string(), arr(counts));
    snap.insert("tau_sum".to_string(), arr(tau));
    snap.insert("rho_sum".to_string(), arr(rho));
    let mut push = BTreeMap::new();
    push.insert("node_id".to_string(), Json::Str("peer-eps".to_string()));
    push.insert("snapshots".to_string(), Json::Arr(vec![Json::Obj(snap)]));
    let (status, resp) = client.post("/v1/sync/push", &Json::Obj(push)).unwrap();
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("accepted").and_then(Json::as_usize), Some(1));

    // A fresh epsilon session warm-starts from the pushed prior and
    // reports locally; its delta then appears on a pull.
    let eps = &[("policy", Json::Str("epsilon".to_string()))];
    let (status, _) = client.post("/v1/suggest", &body("eps-fresh", eps)).unwrap();
    assert_eq!(status, 200);
    let (status, b) = client
        .get(&format!("{}&policy=epsilon", best_query("eps-fresh")))
        .unwrap();
    assert_eq!(status, 200, "{b:?}");
    assert_eq!(b.get("policy").and_then(Json::as_str), Some("epsilon-greedy"));
    assert_eq!(
        b.get("arm").and_then(Json::as_usize),
        Some(5),
        "epsilon session did not warm-start from the fleet prior: {b:?}"
    );
    let m = metrics_text(&mut client);
    assert!(metric_value(&m, "lasp_serve_fleet_warm_starts_total") >= 1.0, "{m}");

    // Report a fresh local measurement on arm 9 and wait for the batch
    // plane to apply it.
    let (status, _) = client
        .post(
            "/v1/report",
            &body(
                "eps-fresh",
                &[
                    ("policy", Json::Str("epsilon".to_string())),
                    ("arm", Json::Num(9.0)),
                    ("time_s", Json::Num(1.0)),
                    ("power_w", Json::Num(5.0)),
                ],
            ),
        )
        .unwrap();
    assert_eq!(status, 202);
    assert!(
        wait_until(
            || {
                let (s, b) = client
                    .get(&format!("{}&policy=epsilon", best_query("eps-fresh")))
                    .unwrap();
                s == 200 && b.get("reports").and_then(Json::as_f64) == Some(1.0)
            },
            Duration::from_secs(10)
        ),
        "epsilon report never applied"
    );

    // The pull (as another peer) merges the pushed snapshot with this
    // node's local epsilon aggregate — the local arm-9 delta must travel.
    let mut pull = BTreeMap::new();
    pull.insert("node_id".to_string(), Json::Str("peer-2".to_string()));
    let (status, resp) = client.post("/v1/sync/pull", &Json::Obj(pull)).unwrap();
    assert_eq!(status, 200);
    let snaps = resp.get("snapshots").and_then(Json::as_arr).unwrap();
    assert_eq!(snaps.len(), 1, "expected one merged epsilon scenario: {resp:?}");
    assert_eq!(snaps[0].get("policy").and_then(Json::as_str), Some("epsilon"));
    let arms = snaps[0].get("arms").and_then(Json::as_arr).unwrap();
    let counts = snaps[0].get("counts").and_then(Json::as_arr).unwrap();
    let pos9 = arms
        .iter()
        .position(|a| a.as_usize() == Some(9))
        .expect("arm 9 missing from merged snapshot");
    // The pushed snapshot carried one (decayed) pull on arm 9; the local
    // epsilon measurement adds a full one on top — if the local delta
    // were dropped the merged count would stay ~1.
    let c9 = counts[pos9].as_f64().unwrap();
    assert!(c9 > 1.5, "locally measured epsilon delta missing from pull: {c9}");

    node.shutdown().unwrap();
}
