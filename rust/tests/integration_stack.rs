//! Cross-module integration tests: apps × devices × bandits × coordinator
//! composed the way the examples and the paper's workflow compose them.

use lasp::apps::{self, AppKind};
use lasp::baselines::{FnEval, RandomSearch, Searcher};
use lasp::coordinator::transfer::{lf_hf_topk_overlap, validate_on_hpc};
use lasp::coordinator::{Fleet, FleetConfig, TuneJob};
use lasp::device::{Device, HpcNode, JetsonNano, NoiseModel, PowerMode};
use lasp::tuning::{oracle_sweep, SessionConfig, TuningSession};
use lasp::util::stats;
use std::time::Duration;

#[test]
fn lasp_beats_random_search_on_every_app_at_equal_budget() {
    // The headline ordering: at 500 evaluations (LF, noisy), LASP's pick
    // should be at least as good as random search's on expected time,
    // averaged across apps.
    let budget = 500;
    let mut lasp_total = 0.0;
    let mut random_total = 0.0;
    for kind in AppKind::all() {
        let sweep = oracle_sweep(
            apps::build(kind).as_ref(),
            &PowerMode::Maxn.spec(),
            0.15,
        );
        let (lasp_pick, _, _) = lasp::experiments::harness::run_lasp(
            kind,
            PowerMode::Maxn,
            budget,
            1.0,
            0.0,
            21,
            NoiseModel::uniform(0.02),
        );
        let mut eval = lasp::experiments::harness::AppEval::new(kind, PowerMode::Maxn, 21);
        let rnd = RandomSearch::new(21, 1.0, 0.0)
            .run(apps::build(kind).space().len(), budget, &mut eval)
            .unwrap();
        lasp_total += sweep[lasp_pick].time_s / sweep[rnd.best_index].time_s;
        random_total += 1.0;
    }
    let ratio = lasp_total / random_total;
    assert!(ratio < 1.10, "LASP/random expected-time ratio {ratio}");
}

#[test]
fn full_paper_workflow_tune_then_transfer() {
    // Fig 1 end to end for one app: LF tuning on the edge, HF validation.
    let app = apps::build(AppKind::Lulesh);
    let device = JetsonNano::new(PowerMode::Maxn, 5);
    let mut session = TuningSession::new(
        app,
        Box::new(device),
        SessionConfig { iterations: 600, alpha: 0.8, beta: 0.2, record_history: true },
    );
    let out = session.run().unwrap();
    let app = apps::build(AppKind::Lulesh);
    let v = validate_on_hpc(app.as_ref(), out.best_index, 5);
    assert!(v.gain_pct > 0.0, "no HF gain: {:?}", v);
    assert!(v.oracle_distance_pct < 40.0, "too far from oracle: {:?}", v);
    // History is complete and the best arm is its mode.
    assert_eq!(out.history.len(), 600);
}

#[test]
fn fleet_with_pjrt_engine_if_artifacts_present() {
    // The full stack: PJRT artifacts on the worker hot path.
    let engine = lasp::runtime::EngineHandle::spawn_default().ok();
    let mut fleet = Fleet::spawn(
        FleetConfig { devices: 2, seed: 11, ..Default::default() },
        engine.clone(),
    )
    .unwrap();
    for app in [AppKind::Kripke, AppKind::Clomp] {
        fleet.submit(TuneJob { app, iterations: 250, alpha: 0.8, beta: 0.2 }).unwrap();
    }
    let results = fleet.drain(Duration::from_secs(300)).unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        let app = apps::build(r.app);
        assert!(r.best_index < app.space().len());
        assert!(r.pulls_of_best >= 1.0);
    }
    fleet.shutdown();
}

#[test]
fn fig2_premise_holds_for_all_apps() {
    // LF and HF top-20 overlap significantly — the premise that makes the
    // whole edge-as-surrogate idea work.
    let edge = PowerMode::Maxn.spec();
    let node = HpcNode::new(0);
    for kind in AppKind::all() {
        let app = apps::build(kind);
        let overlap = lf_hf_topk_overlap(app.as_ref(), &edge, node.spec(), 0.15, 20);
        assert!(overlap >= 5, "{kind}: overlap {overlap}");
    }
}

#[test]
fn noise_degrades_gracefully() {
    // Monotonicity in expectation is too strict for one seed; assert that
    // even at 15% injected noise the tuned config beats default on Clomp.
    let sweep = oracle_sweep(
        apps::build(AppKind::Clomp).as_ref(),
        &PowerMode::Maxn.spec(),
        0.15,
    );
    let default = apps::build(AppKind::Clomp).default_index();
    for noise in [0.05, 0.10, 0.15] {
        let (pick, _, _) = lasp::experiments::harness::run_lasp(
            AppKind::Clomp,
            PowerMode::Maxn,
            600,
            1.0,
            0.0,
            31,
            NoiseModel::uniform(noise),
        );
        assert!(
            sweep[pick].time_s < sweep[default].time_s,
            "noise {noise}: pick {} not better than default {}",
            sweep[pick].time_s,
            sweep[default].time_s
        );
    }
}

#[test]
fn searcher_trait_objects_interchangeable() {
    // All searchers run through the same harness types (API contract).
    let searchers: Vec<Box<dyn Searcher>> = vec![
        Box::new(RandomSearch::new(1, 1.0, 0.0)),
        Box::new(lasp::baselines::SimulatedAnnealing::new(1, 1.0, 0.0)),
        Box::new(lasp::baselines::BlissBo::new(1, 1.0, 0.0)),
        Box::new(lasp::baselines::SuccessiveHalving::new(1, 1.0, 0.0)),
    ];
    for mut s in searchers {
        let mut device = JetsonNano::new(PowerMode::Maxn, 3);
        let app = apps::build(AppKind::Clomp);
        let mut eval = FnEval {
            f: move |i: usize, q: f64| device.run(&app.workload(i, q)),
            fidelity: 0.15,
        };
        let out = s.run(125, 60, &mut eval).unwrap();
        assert!(out.best_index < 125, "{}", s.name());
        assert!(out.evaluations() <= 60);
    }
}

#[test]
fn thermal_throttling_visible_through_full_stack() {
    // Long heavy session on the edge device heats it; the bandit still
    // completes and the device reports elevated temperature.
    let mut device = JetsonNano::new(PowerMode::Maxn, 77);
    let app = apps::build(AppKind::Kripke);
    let mut tuner = lasp::bandit::UcbTuner::new(app.space().len(), 1.0, 0.0);
    use lasp::bandit::Policy;
    for _ in 0..400 {
        let arm = tuner.select();
        let m = device.run(&app.workload(arm, 0.5)); // mid fidelity: heavy
        tuner.update(arm, m.time_s, m.power_w);
    }
    assert!(device.temperature_c() > 50.0, "temp {}", device.temperature_c());
}

#[test]
fn hf_validation_metrics_consistent() {
    let app = apps::build(AppKind::Hypre);
    // Validate the default config: gain ~0, distance > 0 (not oracle).
    let v = validate_on_hpc(app.as_ref(), app.default_index(), 9);
    assert!(v.gain_pct.abs() < 5.0);
    assert!(v.oracle_distance_pct > 0.0);
    // Validate the HF time oracle: distance 0.
    let node = HpcNode::new(9);
    let sweep = oracle_sweep(app.as_ref(), node.spec(), 1.0);
    let times: Vec<f64> = sweep.iter().map(|m| m.time_s).collect();
    let v = validate_on_hpc(app.as_ref(), stats::argmin(&times), 9);
    assert!(v.oracle_distance_pct.abs() < 1e-9);
}
