//! Behavioral-equivalence golden test for the unified-core refactor.
//!
//! The five policies were rebuilt as thin strategy layers over the shared
//! `ArmStats` engine; this test pins their *selection behaviour* to the
//! pre-refactor implementations bit for bit. The "fixtures" are frozen
//! reference implementations: the pre-refactor scoring pipeline
//! (`RewardState` + `filled_means` → `weighted_rewards` → `ucb_scores` /
//! fused `lasp_step`) copied verbatim below, driven through the same
//! deterministic environment and seeds as the live policies. If a future
//! change to the core or the kernels shifts even one selection, the arm
//! sequences diverge and the failing iteration is reported.
//!
//! Both sides share `lasp::util::Rng` (untouched by the refactor); the
//! per-iteration environment consumes a fixed number of draws per round,
//! so sequences stay comparable even past a first divergence.
//!
//! Set `LASP_GOLDEN_REGEN=1` to (re)write the archived sequences to
//! `rust/tests/fixtures/policy_golden.txt`; when that file exists the
//! live sequences are additionally compared against it.

use lasp::bandit::{
    EpsilonGreedy, Policy, SlidingWindowUcb, SubsetTuner, ThompsonSampler, UcbTuner,
};
use lasp::util::Rng;
use std::collections::VecDeque;

// --- Frozen pre-refactor reference implementation ------------------------

const UNPULLED_SCORE: f64 = 1.0e9;
const REWARD_EPS: f64 = 1e-2;
const MINMAX_EPS: f64 = 1e-9;
const DEFAULT_EXPLORATION: f64 = 0.25;

fn ref_argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    let mut best_v = f64::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

/// Pre-refactor `RewardState` (plain vectors, no caches).
#[derive(Clone)]
struct RefState {
    tau_sum: Vec<f64>,
    rho_sum: Vec<f64>,
    counts: Vec<f64>,
    t: f64,
}

impl RefState {
    fn new(k: usize) -> RefState {
        RefState {
            tau_sum: vec![0.0; k],
            rho_sum: vec![0.0; k],
            counts: vec![0.0; k],
            t: 1.0,
        }
    }

    fn k(&self) -> usize {
        self.counts.len()
    }

    fn observe(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.tau_sum[arm] += time_s;
        self.rho_sum[arm] += power_w;
        self.counts[arm] += 1.0;
        self.t += 1.0;
    }

    fn filled_means(&self) -> (Vec<f64>, Vec<f64>) {
        let k = self.k();
        let mut mean_tau = vec![0.0; k];
        let mut mean_rho = vec![0.0; k];
        let mut fill_tau = 0.0;
        let mut fill_rho = 0.0;
        let mut pulled = 0.0f64;
        for i in 0..k {
            if self.counts[i] > 0.0 {
                mean_tau[i] = self.tau_sum[i] / self.counts[i];
                mean_rho[i] = self.rho_sum[i] / self.counts[i];
                fill_tau += mean_tau[i];
                fill_rho += mean_rho[i];
                pulled += 1.0;
            }
        }
        let denom = pulled.max(1.0);
        let (fill_tau, fill_rho) = (fill_tau / denom, fill_rho / denom);
        for i in 0..k {
            if self.counts[i] == 0.0 {
                mean_tau[i] = fill_tau;
                mean_rho[i] = fill_rho;
            }
        }
        (mean_tau, mean_rho)
    }
}

fn ref_minmax_eps(xs: &[f64]) -> Vec<f64> {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let range = (hi - lo).max(MINMAX_EPS);
    xs.iter().map(|x| (x - lo) / range).collect()
}

fn ref_weighted_rewards(mean_tau: &[f64], mean_rho: &[f64], alpha: f64, beta: f64) -> Vec<f64> {
    let tau_hat = ref_minmax_eps(mean_tau);
    let rho_hat = ref_minmax_eps(mean_rho);
    let raw: Vec<f64> = tau_hat
        .iter()
        .zip(&rho_hat)
        .map(|(t, r)| alpha / (t + REWARD_EPS) + beta / (r + REWARD_EPS))
        .collect();
    ref_minmax_eps(&raw)
}

fn ref_ucb_scores(rewards: &[f64], counts: &[f64], t: f64, c: f64) -> Vec<f64> {
    let log_t = t.max(1.0).ln();
    rewards
        .iter()
        .zip(counts)
        .map(|(r, n)| {
            if *n > 0.0 {
                r + c * (2.0 * log_t / n.max(1.0)).sqrt()
            } else {
                UNPULLED_SCORE
            }
        })
        .collect()
}

/// Pre-refactor fused `ScalarBackend::lasp_step` (selection only).
fn ref_lasp_step(state: &RefState, alpha: f64, beta: f64, exploration: f64) -> usize {
    let k = state.k();
    let counts = &state.counts;
    let mut fill_tau = 0.0;
    let mut fill_rho = 0.0;
    let mut pulled = 0.0f64;
    let mut tau_lo = f64::INFINITY;
    let mut tau_hi = f64::NEG_INFINITY;
    let mut rho_lo = f64::INFINITY;
    let mut rho_hi = f64::NEG_INFINITY;
    for i in 0..k {
        if counts[i] > 0.0 {
            let mt = state.tau_sum[i] / counts[i];
            let mr = state.rho_sum[i] / counts[i];
            fill_tau += mt;
            fill_rho += mr;
            pulled += 1.0;
            tau_lo = tau_lo.min(mt);
            tau_hi = tau_hi.max(mt);
            rho_lo = rho_lo.min(mr);
            rho_hi = rho_hi.max(mr);
        }
    }
    let denom = pulled.max(1.0);
    let fill_tau = fill_tau / denom;
    let fill_rho = fill_rho / denom;
    if pulled == 0.0 {
        tau_lo = fill_tau;
        tau_hi = fill_tau;
        rho_lo = fill_rho;
        rho_hi = fill_rho;
    }
    let tau_range = (tau_hi - tau_lo).max(MINMAX_EPS);
    let rho_range = (rho_hi - rho_lo).max(MINMAX_EPS);

    let mut rewards = vec![0.0f64; k];
    let mut raw_lo = f64::INFINITY;
    let mut raw_hi = f64::NEG_INFINITY;
    for i in 0..k {
        let (mt, mr) = if counts[i] > 0.0 {
            (state.tau_sum[i] / counts[i], state.rho_sum[i] / counts[i])
        } else {
            (fill_tau, fill_rho)
        };
        let tau_hat = (mt - tau_lo) / tau_range;
        let rho_hat = (mr - rho_lo) / rho_range;
        let raw = alpha / (tau_hat + REWARD_EPS) + beta / (rho_hat + REWARD_EPS);
        rewards[i] = raw;
        raw_lo = raw_lo.min(raw);
        raw_hi = raw_hi.max(raw);
    }
    let raw_range = (raw_hi - raw_lo).max(MINMAX_EPS);

    let log_t = state.t.max(1.0).ln();
    let bonus_base = 2.0 * log_t;
    let mut best = 0usize;
    let mut best_score = f64::NEG_INFINITY;
    for i in 0..k {
        let r = (rewards[i] - raw_lo) / raw_range;
        let score = if counts[i] > 0.0 {
            r + exploration * (bonus_base / counts[i]).sqrt()
        } else {
            UNPULLED_SCORE
        };
        if score > best_score {
            best_score = score;
            best = i;
        }
    }
    best
}

/// Pre-refactor policy behaviours, each copied verbatim.
enum RefPolicy {
    Ucb {
        state: RefState,
        alpha: f64,
        beta: f64,
    },
    Epsilon {
        state: RefState,
        alpha: f64,
        beta: f64,
        epsilon: f64,
        rng: Rng,
    },
    Thompson {
        state: RefState,
        alpha: f64,
        beta: f64,
        rng: Rng,
        obs_std: f64,
    },
    SwUcb {
        alpha: f64,
        beta: f64,
        window: usize,
        history: VecDeque<(usize, f64, f64)>,
        state: RefState,
    },
    Subset {
        inner: RefState,
        alpha: f64,
        beta: f64,
        candidates: Vec<usize>,
    },
}

impl RefPolicy {
    fn ref_select(&mut self) -> usize {
        match self {
            RefPolicy::Ucb { state, alpha, beta } => {
                ref_lasp_step(state, *alpha, *beta, DEFAULT_EXPLORATION)
            }
            RefPolicy::Epsilon { state, alpha, beta, epsilon, rng } => {
                if let Some(arm) = state.counts.iter().position(|&c| c == 0.0) {
                    return arm;
                }
                if rng.uniform() < *epsilon {
                    return rng.below(state.k());
                }
                let (mt, mr) = state.filled_means();
                let rewards = ref_weighted_rewards(&mt, &mr, *alpha, *beta);
                ref_argmax(&rewards)
            }
            RefPolicy::Thompson { state, alpha, beta, rng, obs_std } => {
                if let Some(arm) = state.counts.iter().position(|&c| c == 0.0) {
                    return arm;
                }
                let (mt, mr) = state.filled_means();
                let rewards = ref_weighted_rewards(&mt, &mr, *alpha, *beta);
                let samples: Vec<f64> = rewards
                    .iter()
                    .zip(&state.counts)
                    .map(|(r, n)| r + rng.normal() * *obs_std / n.max(1.0).sqrt())
                    .collect();
                ref_argmax(&samples)
            }
            RefPolicy::SwUcb { alpha, beta, history, state, .. } => {
                if let Some(arm) = state.counts.iter().position(|&c| c == 0.0) {
                    return arm;
                }
                let (mt, mr) = state.filled_means();
                let rewards = ref_weighted_rewards(&mt, &mr, *alpha, *beta);
                let t_eff = (history.len() as f64).max(1.0);
                let scores = ref_ucb_scores(&rewards, &state.counts, t_eff, DEFAULT_EXPLORATION);
                ref_argmax(&scores)
            }
            RefPolicy::Subset { inner, alpha, beta, candidates } => {
                candidates[ref_lasp_step(inner, *alpha, *beta, DEFAULT_EXPLORATION)]
            }
        }
    }

    fn ref_update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        match self {
            RefPolicy::Ucb { state, .. }
            | RefPolicy::Epsilon { state, .. }
            | RefPolicy::Thompson { state, .. } => state.observe(arm, time_s, power_w),
            RefPolicy::SwUcb { window, history, state, .. } => {
                history.push_back((arm, time_s, power_w));
                state.tau_sum[arm] += time_s;
                state.rho_sum[arm] += power_w;
                state.counts[arm] += 1.0;
                if history.len() > *window {
                    let (old_arm, old_t, old_p) = history.pop_front().unwrap();
                    state.tau_sum[old_arm] -= old_t;
                    state.rho_sum[old_arm] -= old_p;
                    state.counts[old_arm] -= 1.0;
                    if state.counts[old_arm] < 1e-9 {
                        state.counts[old_arm] = 0.0;
                        state.tau_sum[old_arm] = 0.0;
                        state.rho_sum[old_arm] = 0.0;
                    }
                }
            }
            RefPolicy::Subset { inner, candidates, .. } => {
                let pos = candidates
                    .iter()
                    .position(|&c| c == arm)
                    .expect("arm outside reference candidate subset");
                inner.observe(pos, time_s, power_w);
            }
        }
    }
}

// --- Shared deterministic environment -------------------------------------

const ALPHA: f64 = 0.7;
const BETA: f64 = 0.3;

fn base_time(arm: usize) -> f64 {
    0.5 + ((arm * 7919) % 97) as f64 / 40.0
}

fn base_power(arm: usize) -> f64 {
    3.0 + ((arm * 104_729) % 11) as f64 * 0.5
}

/// Minimal select/update surface shared by the live policies and the
/// frozen references.
trait Agent {
    fn select(&mut self) -> usize;
    fn update(&mut self, arm: usize, time_s: f64, power_w: f64);
}

impl Agent for RefPolicy {
    fn select(&mut self) -> usize {
        self.ref_select()
    }
    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        self.ref_update(arm, time_s, power_w)
    }
}

impl Agent for Box<dyn Policy> {
    fn select(&mut self) -> usize {
        (**self).select()
    }
    fn update(&mut self, arm: usize, time_s: f64, power_w: f64) {
        (**self).update(arm, time_s, power_w)
    }
}

/// One scenario: iterate select → measure → update, recording the arm
/// sequence. The environment consumes exactly two rng draws per round,
/// whatever arm was chosen, so ref and live streams stay aligned.
fn run(agent: &mut dyn Agent, iters: usize, env_seed: u64) -> Vec<usize> {
    let mut env = Rng::new(env_seed);
    let mut seq = Vec::with_capacity(iters);
    for _ in 0..iters {
        let arm = agent.select();
        let time = base_time(arm) * env.relative_noise(0.05);
        let power = base_power(arm) * env.relative_noise(0.02);
        agent.update(arm, time, power);
        seq.push(arm);
    }
    seq
}

struct Scenario {
    name: &'static str,
    env_seed: u64,
    live: Box<dyn Policy>,
    reference: RefPolicy,
}

fn scenarios() -> Vec<Scenario> {
    let k = 24;
    let window = 64;
    let (big_k, m, subset_seed) = (2000, 48, 0xD00D);
    vec![
        Scenario {
            name: "ucb",
            env_seed: 0xE0,
            live: Box::new(UcbTuner::new(k, ALPHA, BETA)),
            reference: RefPolicy::Ucb { state: RefState::new(k), alpha: ALPHA, beta: BETA },
        },
        Scenario {
            name: "epsilon",
            env_seed: 0xE1,
            live: Box::new(EpsilonGreedy::new(k, ALPHA, BETA, 0.1, 7)),
            reference: RefPolicy::Epsilon {
                state: RefState::new(k),
                alpha: ALPHA,
                beta: BETA,
                epsilon: 0.1,
                rng: Rng::new(7),
            },
        },
        Scenario {
            name: "thompson",
            env_seed: 0xE2,
            live: Box::new(ThompsonSampler::new(k, ALPHA, BETA, 11)),
            reference: RefPolicy::Thompson {
                state: RefState::new(k),
                alpha: ALPHA,
                beta: BETA,
                rng: Rng::new(11),
                obs_std: 0.25,
            },
        },
        Scenario {
            name: "swucb",
            env_seed: 0xE3,
            live: Box::new(SlidingWindowUcb::new(k, ALPHA, BETA, window)),
            reference: RefPolicy::SwUcb {
                alpha: ALPHA,
                beta: BETA,
                window,
                history: VecDeque::new(),
                state: RefState::new(k),
            },
        },
        Scenario {
            name: "subset",
            env_seed: 0xE4,
            live: Box::new(SubsetTuner::new(big_k, m, ALPHA, BETA, subset_seed)),
            reference: RefPolicy::Subset {
                // The pre-refactor candidate draw, verbatim.
                inner: RefState::new(m),
                alpha: ALPHA,
                beta: BETA,
                candidates: Rng::new(subset_seed).sample_indices(big_k, m),
            },
        },
    ]
}

const ITERS: usize = 400;

#[test]
fn refactored_policies_reproduce_pre_refactor_sequences() {
    let fixture_path = std::path::Path::new("rust/tests/fixtures/policy_golden.txt");
    let regen = std::env::var("LASP_GOLDEN_REGEN").map(|v| v == "1").unwrap_or(false);
    let mut archive = String::new();

    for scenario in scenarios() {
        let Scenario { name, env_seed, mut live, mut reference } = scenario;
        let expected = run(&mut reference, ITERS, env_seed);
        let got = run(&mut live, ITERS, env_seed);
        for (i, (e, g)) in expected.iter().zip(&got).enumerate() {
            assert_eq!(
                g, e,
                "{name}: refactored policy diverged from the pre-refactor \
                 reference at iteration {i}"
            );
        }
        // Eq. 4 consequences agree too.
        let counts_total: f64 = live.counts().iter().sum();
        assert_eq!(counts_total, ITERS as f64, "{name}");
        assert_eq!(live.total_pulls(), ITERS as f64, "{name}");

        archive.push_str(name);
        archive.push(':');
        for (i, arm) in got.iter().enumerate() {
            archive.push(if i == 0 { ' ' } else { ',' });
            archive.push_str(&arm.to_string());
        }
        archive.push('\n');
    }

    if regen {
        std::fs::create_dir_all(fixture_path.parent().unwrap()).unwrap();
        std::fs::write(fixture_path, &archive).unwrap();
    } else if fixture_path.exists() {
        let recorded = std::fs::read_to_string(fixture_path).unwrap();
        assert_eq!(
            archive, recorded,
            "live sequences diverged from the archived fixtures \
             (regenerate with LASP_GOLDEN_REGEN=1 only if the change is intended)"
        );
    }
}
