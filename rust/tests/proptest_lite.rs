//! Property-based tests over coordinator/bandit invariants.
//!
//! `proptest` is unavailable in this offline build (see Cargo.toml), so the
//! same discipline is implemented directly: each property runs against many
//! seeded random cases and reports the failing seed on violation.

use lasp::bandit::{ArmStats, Policy, ScalarBackend, ScoreBackend, Scratch, SubsetTuner, UcbTuner};
use lasp::space::{ParamDef, ParamSpace};
use lasp::util::{stats, Rng};

/// Run `prop` on `cases` seeded inputs; panic with the seed on failure.
fn forall(cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xF00D + seed);
        // A panic inside carries context; wrap to report the seed.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property failed for seed {seed}: {e:?}");
        }
    }
}

fn random_space(rng: &mut Rng) -> ParamSpace {
    let dims = 1 + rng.below(4);
    let params: Vec<ParamDef> = (0..dims)
        .map(|d| {
            let card = 2 + rng.below(6) as i64;
            let vals: Vec<i64> = (0..card).collect();
            let default = vals[rng.below(vals.len())];
            ParamDef::ints(format!("p{d}"), &vals, default)
        })
        .collect();
    ParamSpace::new("random", params)
}

#[test]
fn prop_space_encode_decode_roundtrip() {
    forall(50, |rng| {
        let space = random_space(rng);
        for _ in 0..20 {
            let idx = rng.below(space.len());
            assert_eq!(space.encode_positions(&space.positions(idx)), idx);
            let f = space.features(idx);
            assert_eq!(f.len(), space.dims());
            assert!(f.iter().all(|v| (0.0..=1.0).contains(v)));
        }
        assert!(space.default_index() < space.len());
    });
}

#[test]
fn prop_rewards_always_normalized() {
    // For any observation pattern, Eq. 5 rewards stay in [0, 1] and the
    // best arm's reward is exactly 1 when alpha = 1.
    forall(60, |rng| {
        let k = 2 + rng.below(40);
        let mut state = ArmStats::new(k);
        let pulls = 1 + rng.below(200);
        for _ in 0..pulls {
            state.observe(rng.below(k), rng.range(0.1, 10.0), rng.range(1.0, 12.0));
        }
        let mut scratch = Scratch::new();
        ScalarBackend.lasp_step(&state, 1.0, 0.0, 0.25, &mut scratch).unwrap();
        assert!(scratch.rewards.iter().all(|r| (-1e-12..=1.0 + 1e-12).contains(r)));
        // The arm with the minimum mean time gets reward 1.
        let (mt, _) = state.filled_means();
        let best_mean = stats::argmin(&mt);
        assert!(
            (scratch.rewards[best_mean] - 1.0).abs() < 1e-9,
            "best-mean arm reward {}",
            scratch.rewards[best_mean]
        );
    });
}

#[test]
fn prop_ucb_selection_always_in_range_and_counts_conserved() {
    forall(40, |rng| {
        let k = 2 + rng.below(30);
        let mut tuner = UcbTuner::new(k, 0.7, 0.3);
        let rounds = 5 + rng.below(300);
        for _ in 0..rounds {
            let arm = tuner.select();
            assert!(arm < k);
            tuner.update(arm, rng.range(0.1, 5.0), rng.range(1.0, 10.0));
        }
        assert_eq!(tuner.total_pulls(), rounds as f64);
        assert_eq!(
            tuner.counts().iter().sum::<f64>(),
            rounds as f64,
            "counts conserve pulls"
        );
        assert!(tuner.most_selected() < k);
    });
}

#[test]
fn prop_ucb_never_starves_with_full_exploration() {
    // With c = 1 (textbook UCB1) every arm is pulled infinitely often: over
    // 60·k rounds, no arm stays at its initial single pull.
    forall(20, |rng| {
        let k = 3 + rng.below(10);
        let mut tuner = UcbTuner::new(k, 1.0, 0.0).with_exploration(1.0);
        for _ in 0..60 * k {
            let arm = tuner.select();
            tuner.update(arm, rng.range(0.5, 1.5), 5.0);
        }
        let min_pulls = tuner.counts().iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min_pulls >= 2.0, "an arm starved: {:?}", tuner.counts());
    });
}

#[test]
fn prop_subset_tuner_stays_in_candidates() {
    forall(30, |rng| {
        let k = 100 + rng.below(5000);
        let m = 8 + rng.below(56);
        let mut tuner = SubsetTuner::new(k, m.min(k), 0.8, 0.2, rng.next_u64());
        let cands: std::collections::HashSet<usize> =
            tuner.candidates().iter().copied().collect();
        for _ in 0..200 {
            let arm = tuner.select();
            assert!(cands.contains(&arm));
            tuner.update(arm, rng.range(0.1, 2.0), rng.range(1.0, 9.0));
        }
        // Eq. 4 output is a candidate and counts live in the full space.
        assert!(cands.contains(&tuner.most_selected()));
        assert_eq!(tuner.counts().len(), k);
    });
}

#[test]
fn prop_scalar_step_deterministic() {
    // Same state must always produce the same selection (pure function).
    forall(30, |rng| {
        let k = 2 + rng.below(50);
        let mut state = ArmStats::new(k);
        for _ in 0..rng.below(100) + k {
            state.observe(rng.below(k), rng.range(0.1, 4.0), rng.range(1.0, 8.0));
        }
        let mut sa = Scratch::new();
        let mut sb = Scratch::new();
        let a = ScalarBackend.lasp_step(&state, 0.8, 0.2, 0.25, &mut sa).unwrap();
        let b = ScalarBackend.lasp_step(&state, 0.8, 0.2, 0.25, &mut sb).unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(sa.rewards, sb.rewards);
    });
}

#[test]
fn prop_device_time_positive_and_power_capped() {
    // Any sane workload on any mode yields positive time and capped power.
    use lasp::apps::Workload;
    use lasp::device::{Device, JetsonNano, PowerMode};
    forall(40, |rng| {
        let mode = if rng.uniform() < 0.5 { PowerMode::Maxn } else { PowerMode::FiveW };
        let budget = mode.spec().power_budget_w;
        let mut device = JetsonNano::new(mode, rng.next_u64());
        for _ in 0..20 {
            let w = Workload {
                compute: rng.range(0.01, 50.0),
                mem_intensity: rng.uniform(),
                parallel_frac: rng.uniform(),
                overhead: rng.range(0.0, 0.5),
            };
            let m = device.run(&w);
            assert!(m.time_s > 0.0 && m.time_s.is_finite());
            // Intrinsic noise is 1.5%; allow its excursion above the cap.
            assert!(m.power_w <= budget * 1.05, "{} > {budget}", m.power_w);
        }
    });
}

#[test]
fn prop_fidelity_monotone_in_expected_time() {
    // Higher fidelity never makes the expected (noise-free) run faster.
    use lasp::apps::{self, AppKind};
    use lasp::device::{run_with_cap, PowerMode};
    forall(30, |rng| {
        let kind = AppKind::all()[rng.below(4)];
        let app = apps::build(kind);
        let spec = PowerMode::Maxn.spec();
        let idx = rng.below(app.space().len());
        let q1 = rng.uniform();
        let q2 = (q1 + rng.uniform() * (1.0 - q1)).min(1.0);
        let t1 = run_with_cap(&spec, &app.workload(idx, q1)).time_s;
        let t2 = run_with_cap(&spec, &app.workload(idx, q2)).time_s;
        assert!(t2 >= t1 - 1e-9, "{kind} #{idx}: q{q1:.2}->{t1}, q{q2:.2}->{t2}");
    });
}

#[test]
fn prop_minmax_idempotent_on_unit_range() {
    forall(40, |rng| {
        let n = 2 + rng.below(100);
        let xs: Vec<f64> = (0..n).map(|_| rng.range(-50.0, 50.0)).collect();
        let once = stats::minmax(&xs);
        let twice = stats::minmax(&once);
        for (a, b) in once.iter().zip(&twice) {
            assert!((a - b).abs() < 1e-9);
        }
    });
}
