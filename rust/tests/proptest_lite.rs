//! Property-based tests over coordinator/bandit invariants.
//!
//! `proptest` is unavailable in this offline build (see Cargo.toml), so the
//! same discipline is implemented directly: each property runs against many
//! seeded random cases and reports the failing seed on violation.

use lasp::bandit::{ArmStats, Policy, ScalarBackend, ScoreBackend, Scratch, SubsetTuner, UcbTuner};
use lasp::space::{ParamDef, ParamSpace};
use lasp::util::json::{JsonSlice, JsonWriter};
use lasp::util::{stats, Rng};

/// Run `prop` on `cases` seeded inputs; panic with the seed on failure.
fn forall(cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xF00D + seed);
        // A panic inside carries context; wrap to report the seed.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property failed for seed {seed}: {e:?}");
        }
    }
}

fn random_space(rng: &mut Rng) -> ParamSpace {
    let dims = 1 + rng.below(4);
    let params: Vec<ParamDef> = (0..dims)
        .map(|d| {
            let card = 2 + rng.below(6) as i64;
            let vals: Vec<i64> = (0..card).collect();
            let default = vals[rng.below(vals.len())];
            ParamDef::ints(format!("p{d}"), &vals, default)
        })
        .collect();
    ParamSpace::new("random", params)
}

#[test]
fn prop_space_encode_decode_roundtrip() {
    forall(50, |rng| {
        let space = random_space(rng);
        for _ in 0..20 {
            let idx = rng.below(space.len());
            assert_eq!(space.encode_positions(&space.positions(idx)), idx);
            let f = space.features(idx);
            assert_eq!(f.len(), space.dims());
            assert!(f.iter().all(|v| (0.0..=1.0).contains(v)));
        }
        assert!(space.default_index() < space.len());
    });
}

#[test]
fn prop_rewards_always_normalized() {
    // For any observation pattern, Eq. 5 rewards stay in [0, 1] and the
    // best arm's reward is exactly 1 when alpha = 1.
    forall(60, |rng| {
        let k = 2 + rng.below(40);
        let mut state = ArmStats::new(k);
        let pulls = 1 + rng.below(200);
        for _ in 0..pulls {
            state.observe(rng.below(k), rng.range(0.1, 10.0), rng.range(1.0, 12.0));
        }
        let mut scratch = Scratch::new();
        ScalarBackend.lasp_step(&state, 1.0, 0.0, 0.25, &mut scratch).unwrap();
        assert!(scratch.rewards.iter().all(|r| (-1e-12..=1.0 + 1e-12).contains(r)));
        // The arm with the minimum mean time gets reward 1.
        let (mt, _) = state.filled_means();
        let best_mean = stats::argmin(&mt);
        assert!(
            (scratch.rewards[best_mean] - 1.0).abs() < 1e-9,
            "best-mean arm reward {}",
            scratch.rewards[best_mean]
        );
    });
}

#[test]
fn prop_ucb_selection_always_in_range_and_counts_conserved() {
    forall(40, |rng| {
        let k = 2 + rng.below(30);
        let mut tuner = UcbTuner::new(k, 0.7, 0.3);
        let rounds = 5 + rng.below(300);
        for _ in 0..rounds {
            let arm = tuner.select();
            assert!(arm < k);
            tuner.update(arm, rng.range(0.1, 5.0), rng.range(1.0, 10.0));
        }
        assert_eq!(tuner.total_pulls(), rounds as f64);
        assert_eq!(
            tuner.counts().iter().sum::<f64>(),
            rounds as f64,
            "counts conserve pulls"
        );
        assert!(tuner.most_selected() < k);
    });
}

#[test]
fn prop_ucb_never_starves_with_full_exploration() {
    // With c = 1 (textbook UCB1) every arm is pulled infinitely often: over
    // 60·k rounds, no arm stays at its initial single pull.
    forall(20, |rng| {
        let k = 3 + rng.below(10);
        let mut tuner = UcbTuner::new(k, 1.0, 0.0).with_exploration(1.0);
        for _ in 0..60 * k {
            let arm = tuner.select();
            tuner.update(arm, rng.range(0.5, 1.5), 5.0);
        }
        let min_pulls = tuner.counts().iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min_pulls >= 2.0, "an arm starved: {:?}", tuner.counts());
    });
}

#[test]
fn prop_subset_tuner_stays_in_candidates() {
    forall(30, |rng| {
        let k = 100 + rng.below(5000);
        let m = 8 + rng.below(56);
        let mut tuner = SubsetTuner::new(k, m.min(k), 0.8, 0.2, rng.next_u64());
        let cands: std::collections::HashSet<usize> =
            tuner.candidates().iter().copied().collect();
        for _ in 0..200 {
            let arm = tuner.select();
            assert!(cands.contains(&arm));
            tuner.update(arm, rng.range(0.1, 2.0), rng.range(1.0, 9.0));
        }
        // Eq. 4 output is a candidate and counts live in the full space.
        assert!(cands.contains(&tuner.most_selected()));
        assert_eq!(tuner.counts().len(), k);
    });
}

#[test]
fn prop_scalar_step_deterministic() {
    // Same state must always produce the same selection (pure function).
    forall(30, |rng| {
        let k = 2 + rng.below(50);
        let mut state = ArmStats::new(k);
        for _ in 0..rng.below(100) + k {
            state.observe(rng.below(k), rng.range(0.1, 4.0), rng.range(1.0, 8.0));
        }
        let mut sa = Scratch::new();
        let mut sb = Scratch::new();
        let a = ScalarBackend.lasp_step(&state, 0.8, 0.2, 0.25, &mut sa).unwrap();
        let b = ScalarBackend.lasp_step(&state, 0.8, 0.2, 0.25, &mut sb).unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(sa.rewards, sb.rewards);
    });
}

#[test]
fn prop_device_time_positive_and_power_capped() {
    // Any sane workload on any mode yields positive time and capped power.
    use lasp::apps::Workload;
    use lasp::device::{Device, JetsonNano, PowerMode};
    forall(40, |rng| {
        let mode = if rng.uniform() < 0.5 { PowerMode::Maxn } else { PowerMode::FiveW };
        let budget = mode.spec().power_budget_w;
        let mut device = JetsonNano::new(mode, rng.next_u64());
        for _ in 0..20 {
            let w = Workload {
                compute: rng.range(0.01, 50.0),
                mem_intensity: rng.uniform(),
                parallel_frac: rng.uniform(),
                overhead: rng.range(0.0, 0.5),
            };
            let m = device.run(&w);
            assert!(m.time_s > 0.0 && m.time_s.is_finite());
            // Intrinsic noise is 1.5%; allow its excursion above the cap.
            assert!(m.power_w <= budget * 1.05, "{} > {budget}", m.power_w);
        }
    });
}

#[test]
fn prop_fidelity_monotone_in_expected_time() {
    // Higher fidelity never makes the expected (noise-free) run faster.
    use lasp::apps::{self, AppKind};
    use lasp::device::{run_with_cap, PowerMode};
    forall(30, |rng| {
        let kind = AppKind::all()[rng.below(4)];
        let app = apps::build(kind);
        let spec = PowerMode::Maxn.spec();
        let idx = rng.below(app.space().len());
        let q1 = rng.uniform();
        let q2 = (q1 + rng.uniform() * (1.0 - q1)).min(1.0);
        let t1 = run_with_cap(&spec, &app.workload(idx, q1)).time_s;
        let t2 = run_with_cap(&spec, &app.workload(idx, q2)).time_s;
        assert!(t2 >= t1 - 1e-9, "{kind} #{idx}: q{q1:.2}->{t1}, q{q2:.2}->{t2}");
    });
}

// --- Batch endpoint properties --------------------------------------------

/// Random client-id strings exercising the escape paths of the borrowed
/// codec: quotes, backslashes, slashes, multi-byte UTF-8, spaces.
fn random_client_id(rng: &mut Rng) -> String {
    const POOL: &[char] = &['a', 'B', '7', '_', '-', '"', '\\', '/', 'é', '☃', ' ', '.'];
    let len = 1 + rng.below(12);
    (0..len).map(|_| POOL[rng.below(POOL.len())]).collect()
}

/// One random batch entry; `report` adds the measurement triple.
struct BatchEntry {
    client_id: String,
    alpha: f64,
    beta: f64,
    arm: usize,
    time_s: f64,
    power_w: f64,
}

fn random_entries(rng: &mut Rng, n: usize) -> Vec<BatchEntry> {
    (0..n)
        .map(|_| BatchEntry {
            client_id: random_client_id(rng),
            alpha: rng.uniform(),
            beta: rng.uniform(),
            arm: rng.below(64),
            time_s: rng.range(0.01, 10.0),
            power_w: rng.range(0.5, 15.0),
        })
        .collect()
}

fn write_entries(buf: &mut Vec<u8>, entries: &[BatchEntry], report: bool) {
    buf.clear();
    let mut w = JsonWriter::new(buf);
    w.begin_obj();
    w.key("entries");
    w.begin_arr();
    for e in entries {
        w.begin_obj();
        w.field_str("client_id", &e.client_id);
        w.field_str("app", "clomp");
        w.field_str("device", "maxn");
        w.field_num("alpha", e.alpha);
        w.field_num("beta", e.beta);
        if report {
            w.field_num("arm", e.arm as f64);
            w.field_num("time_s", e.time_s);
            w.field_num("power_w", e.power_w);
        }
        w.end_obj();
    }
    w.end_arr();
    w.end_obj();
}

#[test]
fn prop_batch_bodies_roundtrip_borrowed_codec() {
    // Any well-formed batch written by `JsonWriter` reads back through
    // `JsonSlice` (the serve-side parser) with every field intact — keys
    // in order, strings unescaped to the original, numbers bit-identical.
    forall(60, |rng| {
        let n = 1 + rng.below(8);
        let report = rng.uniform() < 0.5;
        let entries = random_entries(rng, n);
        let mut buf = Vec::new();
        write_entries(&mut buf, &entries, report);

        let v = JsonSlice::parse(&buf).expect("writer output parses");
        assert!(!v.has_duplicate_keys());
        let arr = v.get("entries").expect("entries key");
        assert!(arr.is_arr());
        let mut seen = 0usize;
        for (i, item) in arr.items().enumerate() {
            assert!(item.is_obj());
            assert!(!item.has_duplicate_keys());
            let keys: Vec<String> = item
                .fields()
                .map(|(k, _)| String::from_utf8(k.to_vec()).unwrap())
                .collect();
            let mut expect = vec!["client_id", "app", "device", "alpha", "beta"];
            if report {
                expect.extend(["arm", "time_s", "power_w"]);
            }
            assert_eq!(keys, expect, "field order survives the round-trip");
            let e = &entries[i];
            assert_eq!(item.get("client_id").unwrap().as_str().unwrap(), e.client_id);
            assert_eq!(
                item.get("alpha").unwrap().as_f64().unwrap().to_bits(),
                e.alpha.to_bits()
            );
            assert_eq!(item.get("beta").unwrap().as_f64().unwrap().to_bits(), e.beta.to_bits());
            if report {
                assert_eq!(item.get("arm").unwrap().as_usize().unwrap(), e.arm);
                assert_eq!(
                    item.get("time_s").unwrap().as_f64().unwrap().to_bits(),
                    e.time_s.to_bits()
                );
                assert_eq!(
                    item.get("power_w").unwrap().as_f64().unwrap().to_bits(),
                    e.power_w.to_bits()
                );
            }
            seen += 1;
        }
        assert_eq!(seen, n);
    });
}

#[test]
fn prop_malformed_batches_always_4xx_with_no_state_applied() {
    // Batch ingestion is atomic per request at validation time: any
    // mutation — truncation, duplicate keys, oversized batches, bad
    // UTF-8, empty/nonsense entries — must yield a 4xx AND leave every
    // observable counter (suggests, enqueued/applied reports, sessions)
    // exactly where it was.
    use lasp::serve::{start, HttpClient, ServeConfig};
    use std::time::Duration;

    let handle = start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 2,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    fn metric_value(text: &str, name: &str) -> f64 {
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix(name) {
                if let Some(v) =
                    rest.strip_prefix(' ').and_then(|r| r.trim().parse::<f64>().ok())
                {
                    return v;
                }
            }
        }
        0.0
    }
    const WATCHED: &[&str] = &[
        "lasp_serve_suggests_total",
        "lasp_serve_reports_enqueued_total",
        "lasp_serve_reports_applied_total",
        "lasp_serve_reports_dropped_total",
        "lasp_serve_sessions_created_total",
        "lasp_serve_sessions",
        "lasp_serve_batch_size_count",
    ];
    let snapshot = |client: &mut HttpClient| -> Vec<f64> {
        let (status, page) = client.get("/metrics").unwrap();
        assert_eq!(status, 200);
        let text = page.as_str().unwrap_or_default().to_string();
        WATCHED.iter().map(|m| metric_value(&text, m)).collect()
    };

    // Sanity: the generator produces bodies both endpoints accept.
    let mut rng = Rng::new(0xACCE97);
    let mut buf = Vec::new();
    let sane = random_entries(&mut rng, 3);
    write_entries(&mut buf, &sane, false);
    assert_eq!(client.post_slice("/v1/suggest/batch", &buf).unwrap(), 200);
    write_entries(&mut buf, &sane, true);
    assert_eq!(client.post_slice("/v1/report/batch", &buf).unwrap(), 202);

    // Drain the sanity reports before snapshotting: they apply
    // asynchronously on the shard workers, and a straddling apply would
    // look like a rejected batch mutating state.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let (status, page) = client.get("/metrics").unwrap();
        assert_eq!(status, 200);
        let text = page.as_str().unwrap_or_default().to_string();
        let settled = metric_value(&text, "lasp_serve_reports_applied_total")
            + metric_value(&text, "lasp_serve_reports_rejected_total");
        if settled >= sane.len() as f64 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "sanity reports never drained");
        std::thread::sleep(Duration::from_millis(5));
    }

    for seed in 0..48u64 {
        let mut rng = Rng::new(0xBA7C + seed);
        let report = rng.uniform() < 0.5;
        let path = if report { "/v1/report/batch" } else { "/v1/suggest/batch" };
        let entries = random_entries(&mut rng, 1 + rng.below(6));
        write_entries(&mut buf, &entries, report);

        let mutated: Vec<u8> = match seed % 6 {
            // Truncation: the top-level object never closes, so every
            // strict prefix is invalid JSON.
            0 => buf[..rng.below(buf.len().max(2) - 1)].to_vec(),
            // Duplicate key at the top level.
            1 => {
                let mut b = b"{\"entries\":[],".to_vec();
                b.extend_from_slice(&buf[1..]);
                b
            }
            // Duplicate key inside an entry: splice a second alpha in
            // right after each entry opens.
            2 => {
                let s = String::from_utf8(buf.clone()).unwrap();
                s.replace("{\"client_id\"", "{\"alpha\":0.5,\"client_id\"").into_bytes()
            }
            // Oversized batch: one valid entry repeated past the cap.
            3 => {
                let mut one = Vec::new();
                write_entries(&mut one, &random_entries(&mut rng, 1), report);
                let s = String::from_utf8(one).unwrap();
                let entry = s
                    .strip_prefix("{\"entries\":[")
                    .and_then(|x| x.strip_suffix("]}"))
                    .unwrap()
                    .to_string();
                let mut b = String::from("{\"entries\":[");
                for i in 0..257 {
                    if i > 0 {
                        b.push(',');
                    }
                    b.push_str(&entry);
                }
                b.push_str("]}");
                b.into_bytes()
            }
            // Bad UTF-8 inside a string value.
            4 => {
                let mut b = b"{\"entries\":[{\"client_id\":\"Z\",\"app\":\"clomp\"}]}".to_vec();
                let z = b.iter().position(|&c| c == b'Z').unwrap();
                b[z] = 0xFF;
                b
            }
            // Structurally wrong: empty batch or non-array entries.
            _ => {
                if rng.uniform() < 0.5 {
                    b"{\"entries\":[]}".to_vec()
                } else {
                    b"{\"entries\":7}".to_vec()
                }
            }
        };

        let before = snapshot(&mut client);
        let status = client.post_slice(path, &mutated).unwrap();
        assert!(
            (400..500).contains(&status),
            "seed {seed}: mutated batch ({}) got {status}, want 4xx: {}",
            seed % 6,
            String::from_utf8_lossy(&mutated[..mutated.len().min(120)])
        );
        let after = snapshot(&mut client);
        assert_eq!(
            after, before,
            "seed {seed}: a rejected batch (mutation {}) changed observable state",
            seed % 6
        );
    }

    drop(client);
    handle.shutdown().unwrap();
}

#[test]
fn prop_minmax_idempotent_on_unit_range() {
    forall(40, |rng| {
        let n = 2 + rng.below(100);
        let xs: Vec<f64> = (0..n).map(|_| rng.range(-50.0, 50.0)).collect();
        let once = stats::minmax(&xs);
        let twice = stats::minmax(&once);
        for (a, b) in once.iter().zip(&twice) {
            assert!((a - b).abs() < 1e-9);
        }
    });
}
