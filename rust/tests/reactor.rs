//! Reactor-transport integration tests: behaviours only an event-driven
//! transport exhibits. A slow-reading client whose responses park on
//! writability must not stall the other connections multiplexed on the
//! same event loop, and a thousand idle keep-alive connections must not
//! tax the suggest hot path.

#![cfg(unix)]

use lasp::serve::transport::poller;
use lasp::serve::{start, HttpClient, ServeConfig, ServerHandle, TransportKind};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

fn boot(event_loops: usize) -> ServerHandle {
    start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        transport: TransportKind::Reactor,
        event_loops,
        shards: 2,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .unwrap()
}

fn suggest_body(client: &str) -> String {
    format!(
        "{{\"client_id\":\"{client}\",\"app\":\"clomp\",\"device\":\"maxn\",\
         \"alpha\":1.0,\"beta\":0.0}}"
    )
}

#[test]
fn parked_slow_writer_does_not_stall_other_connections_on_the_loop() {
    // ONE event loop, so the parked connection and the healthy one are
    // guaranteed to share it.
    let handle = boot(1);
    let addr = handle.addr();
    let stats = handle.transport_stats();

    // Client A pipelines far more /metrics responses than the socket
    // buffers can hold and reads none of them: the loop's writes must
    // eventually park A on writability instead of blocking the thread.
    const PIPELINED: usize = 2_000;
    let mut slow = TcpStream::connect(addr).unwrap();
    slow.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let burst: Vec<u8> = b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n".repeat(PIPELINED);
    slow.write_all(&burst).unwrap();

    // Wait until the write path actually hit backpressure.
    let deadline = Instant::now() + Duration::from_secs(10);
    while stats.write_backpressure.load(Ordering::Relaxed) == 0 {
        assert!(
            Instant::now() < deadline,
            "server never parked the slow writer; raise PIPELINED if socket buffers grew"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // Client B shares the (single) event loop with the parked A and must
    // keep completing round-trips promptly.
    let mut healthy =
        HttpClient::connect_with_timeout(&addr.to_string(), Duration::from_secs(5)).unwrap();
    let payload = suggest_body("reactor-healthy");
    let t0 = Instant::now();
    for _ in 0..50 {
        assert_eq!(healthy.post_slice("/v1/suggest", payload.as_bytes()).unwrap(), 200);
    }
    assert_eq!(healthy.reconnects(), 0, "the healthy connection must never be dropped");
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(5),
        "50 round-trips took {elapsed:?} while a peer connection was parked"
    );

    // Drain A: once the client reads, the parked connection resumes and
    // every pipelined request is eventually answered. Responses are
    // counted by status line with a streaming window — the kept tail is
    // one byte shorter than the needle, so no match is counted twice.
    let needle = b"HTTP/1.1 200 OK\r\n";
    let mut served = 0usize;
    let mut tail: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 64 * 1024];
    while served < PIPELINED {
        let n = slow.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed the parked connection after {served} responses");
        tail.extend_from_slice(&chunk[..n]);
        served += tail.windows(needle.len()).filter(|w| *w == needle).count();
        let keep_from = tail.len().saturating_sub(needle.len() - 1);
        tail.drain(..keep_from);
    }
    drop(slow);
    drop(healthy);
    handle.shutdown().unwrap();
}

#[test]
fn thousand_idle_connections_leave_suggest_latency_unaffected() {
    poller::raise_nofile_limit(8192).ok();
    let handle = boot(2);
    let addr = handle.addr();
    let stats = handle.transport_stats();

    // Hold 1000 idle keep-alive connections.
    let mut idle: Vec<TcpStream> = Vec::with_capacity(1000);
    for _ in 0..1000 {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        idle.push(s);
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while stats.conns_open.load(Ordering::Relaxed) < 1000 {
        assert!(
            Instant::now() < deadline,
            "only {} connections adopted",
            stats.conns_open.load(Ordering::Relaxed)
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // An active client's suggest latency must not regress from the idle
    // herd: idle connections produce no readiness events, so the loops
    // do O(ready) work, not O(open).
    let mut client =
        HttpClient::connect_with_timeout(&addr.to_string(), Duration::from_secs(5)).unwrap();
    let payload = suggest_body("reactor-idle-herd");
    for _ in 0..50 {
        assert_eq!(client.post_slice("/v1/suggest", payload.as_bytes()).unwrap(), 200);
    }
    let mut latencies: Vec<f64> = Vec::with_capacity(300);
    for _ in 0..300 {
        let t0 = Instant::now();
        assert_eq!(client.post_slice("/v1/suggest", payload.as_bytes()).unwrap(), 200);
        latencies.push(t0.elapsed().as_secs_f64());
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = latencies[(latencies.len() * 99) / 100 - 1];
    assert!(
        p99 < 0.25,
        "suggest p99 {:.1}ms with 1000 idle connections held",
        p99 * 1e3
    );

    // The idle connections are still live — a sample of them must still
    // serve requests after sitting out the whole run.
    for s in idle.iter_mut().step_by(333) {
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut buf = [0u8; 1024];
        let n = s.read(&mut buf).unwrap();
        assert!(
            buf[..n].starts_with(b"HTTP/1.1 200 OK"),
            "idle connection no longer serves: {}",
            String::from_utf8_lossy(&buf[..n])
        );
    }
    drop(idle);
    drop(client);
    handle.shutdown().unwrap();
}
