//! Hot-path integration tests for the zero-allocation serve transport:
//! pipelined keep-alive requests, split reads across TCP segments, header
//! limits (431), malformed request lines, a serve_restart-style
//! concurrency pass through the rewritten parser, and the steady-state
//! allocation contract observed end-to-end through a real service.

use lasp::serve::{start, HttpClient, ServeConfig};
use lasp::util::json::{Json, JsonSlice};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

fn boot(workers: usize, shards: usize) -> lasp::serve::ServerHandle {
    start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        shards,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .unwrap()
}

fn body(client: &str, app: &str, extra: &[(&str, Json)]) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(client.to_string()));
    obj.insert("app".to_string(), Json::Str(app.to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    obj.insert("alpha".to_string(), Json::Num(1.0));
    obj.insert("beta".to_string(), Json::Num(0.0));
    for (k, v) in extra {
        obj.insert((*k).to_string(), v.clone());
    }
    Json::Obj(obj).to_string()
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one full HTTP response (head + declared body) off `s`.
fn read_one_response(s: &mut TcpStream) -> String {
    let mut raw = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        if let Some(hdr_end) = find_subsequence(&raw, b"\r\n\r\n") {
            let head = String::from_utf8_lossy(&raw[..hdr_end]);
            let clen: usize = head
                .lines()
                .filter_map(|l| l.split_once(':'))
                .find(|(name, _)| name.trim().eq_ignore_ascii_case("content-length"))
                .and_then(|(_, value)| value.trim().parse().ok())
                .unwrap_or(0);
            if raw.len() >= hdr_end + 4 + clen {
                return String::from_utf8_lossy(&raw[..hdr_end + 4 + clen]).into_owned();
            }
        }
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "connection closed early: {}", String::from_utf8_lossy(&raw));
        raw.extend_from_slice(&buf[..n]);
    }
}

#[test]
fn pipelined_suggests_on_one_connection() {
    let handle = boot(2, 2);
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    let payload = body("pipeline", "clomp", &[]);
    let one = format!(
        "POST /v1/suggest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{payload}",
        payload.len()
    );
    // Three requests in a single TCP segment: the parser must answer all
    // three, in order, on the same connection.
    let burst = one.repeat(3);
    s.write_all(burst.as_bytes()).unwrap();
    for _ in 0..3 {
        let resp = read_one_response(&mut s);
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("\"arm\":"), "{resp}");
    }
    drop(s);
    handle.shutdown().unwrap();
}

#[test]
fn split_reads_across_segments() {
    let handle = boot(2, 2);
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    let payload = body("dribble", "kripke", &[]);
    let req = format!(
        "POST /v1/suggest HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{payload}",
        payload.len()
    );
    for chunk in req.as_bytes().chunks(7) {
        s.write_all(chunk).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let resp = read_one_response(&mut s);
    assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
    drop(s);
    handle.shutdown().unwrap();
}

#[test]
fn oversized_headers_rejected_431() {
    let handle = boot(2, 2);
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    let mut req = b"GET /healthz HTTP/1.1\r\nX-Pad: ".to_vec();
    req.extend(std::iter::repeat(b'p').take(20 * 1024));
    req.extend_from_slice(b"\r\n\r\n");
    s.write_all(&req).unwrap();
    let resp = read_one_response(&mut s);
    assert!(resp.starts_with("HTTP/1.1 431"), "{resp}");
    let stats = handle.transport_stats();
    assert!(stats.rejected_431.load(Ordering::Relaxed) >= 1);
    drop(s);
    handle.shutdown().unwrap();
}

#[test]
fn malformed_request_line_rejected_400() {
    let handle = boot(2, 2);
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    s.write_all(b"GARBAGE\r\n\r\n").unwrap();
    let resp = read_one_response(&mut s);
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    drop(s);
    handle.shutdown().unwrap();
}

#[test]
fn concurrent_mixed_traffic_through_new_parser() {
    // serve_restart-style pass: many threads drive suggest/report through
    // the rewritten buffer-reuse path; every report must land.
    let handle = boot(8, 4);
    let addr = handle.addr().to_string();
    let apps = ["clomp", "kripke", "lulesh"];
    let rounds = 30usize;
    let mut workers = vec![];
    for t in 0..8usize {
        let addr = addr.clone();
        let app = apps[t % apps.len()].to_string();
        workers.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(&addr).unwrap();
            let client_id = format!("hot-{t}");
            for _ in 0..rounds {
                let payload = body(&client_id, &app, &[]);
                let status = client.post_slice("/v1/suggest", payload.as_bytes()).unwrap();
                assert_eq!(status, 200);
                let arm = JsonSlice::parse(client.last_body())
                    .unwrap()
                    .get("arm")
                    .and_then(|v| v.as_usize())
                    .unwrap();
                let payload = body(
                    &client_id,
                    &app,
                    &[
                        ("arm", Json::Num(arm as f64)),
                        ("time_s", Json::Num(0.5 + (arm % 7) as f64 * 0.1)),
                        ("power_w", Json::Num(5.0)),
                    ],
                );
                let status = client.post_slice("/v1/report", payload.as_bytes()).unwrap();
                assert_eq!(status, 202);
            }
            assert_eq!(client.reconnects(), 0, "keep-alive must hold for the whole run");
        }));
    }
    for w in workers {
        w.join().unwrap();
    }

    // All reports drain through the batched updaters.
    let mut probe = HttpClient::connect(&addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    for t in 0..8usize {
        let app = apps[t % apps.len()];
        let q = format!("/v1/best?client_id=hot-{t}&app={app}&device=maxn&alpha=1.0&beta=0.0");
        loop {
            let (status, b) = probe.get(&q).unwrap();
            if status == 200
                && b.get("total_pulls").and_then(Json::as_f64) == Some(rounds as f64)
            {
                break;
            }
            assert!(Instant::now() < deadline, "reports never applied for hot-{t}: {b:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    // The transport counters are live on /metrics.
    let (status, page) = probe.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let text = page.as_str().unwrap_or_default().to_string();
    assert!(text.contains("lasp_serve_transport_requests_total"), "{text}");
    assert!(text.contains("lasp_serve_transport_alloc_events_total"), "{text}");
    // Queue-full drops are counted, never silent — the family must exist
    // (and stay zero on an unloaded queue) so operators can alert on it.
    assert!(text.contains("lasp_serve_reports_dropped_total 0"), "{text}");
    assert!(text.contains("lasp_serve_fleet_sync_state 0"), "{text}");
    handle.shutdown().unwrap();
}

#[test]
fn undecodable_query_param_is_400_not_defaulted() {
    // A present-but-mangled parameter must be rejected, never silently
    // replaced by the parameter's default (which would address a
    // different session).
    let handle = boot(2, 2);
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();
    let (status, resp) = client
        .get("/v1/best?client_id=x&app=clomp&policy=%FF")
        .unwrap();
    assert_eq!(status, 400, "{resp:?}");
    let (status, resp) = client.get("/v1/best?client_id=%FF&app=clomp").unwrap();
    assert_eq!(status, 400, "{resp:?}");
    drop(client);
    handle.shutdown().unwrap();
}

#[test]
fn steady_state_suggest_is_allocation_free_end_to_end() {
    let handle = boot(2, 2);
    let addr = handle.addr().to_string();
    let stats = handle.transport_stats();
    let mut client = HttpClient::connect(&addr).unwrap();
    let payload = body("steady", "clomp", &[]);

    // Warmup: buffers reach their high-water marks — the transport's
    // per-connection buffers AND the session's bandit-core scratch.
    for _ in 0..20 {
        assert_eq!(client.post_slice("/v1/suggest", payload.as_bytes()).unwrap(), 200);
    }
    let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
    let scratch_before = handle.bandit_scratch_growths();
    assert!(scratch_before > 0, "warmup never touched the bandit scratch");
    for _ in 0..300 {
        assert_eq!(client.post_slice("/v1/suggest", payload.as_bytes()).unwrap(), 200);
    }
    let allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
    assert_eq!(
        allocs, 0,
        "HTTP+JSON layers performed {allocs} buffer growths over 300 steady-state suggests"
    );
    // The zero-allocation contract extends through the bandit core: the
    // per-session scoring scratch must stay at its high-water mark.
    let scratch_growths = handle.bandit_scratch_growths() - scratch_before;
    assert_eq!(
        scratch_growths, 0,
        "bandit core grew its scratch {scratch_growths} times over 300 steady-state suggests"
    );
    drop(client);
    handle.shutdown().unwrap();
}

#[test]
fn steady_state_suggest_stays_allocation_free_with_tracing_enabled() {
    // The flight recorder rides the suggest hot path (ReqStart + Suggest +
    // ReqEnd per request); the zero-allocation contract must survive it,
    // including with the trace-file writer draining in the background.
    let dir = std::env::temp_dir().join(format!("lasp-hotpath-trace-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("serve.lasptrc");
    let handle = start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 2,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        trace_file: Some(trace_path.clone()),
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();
    let stats = handle.transport_stats();
    let mut client = HttpClient::connect(&addr).unwrap();
    let payload = body("steady-trace", "clomp", &[]);

    for _ in 0..20 {
        assert_eq!(client.post_slice("/v1/suggest", payload.as_bytes()).unwrap(), 200);
    }
    let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
    let scratch_before = handle.bandit_scratch_growths();
    let recorded_before = handle.recorder().recorded();
    for _ in 0..300 {
        assert_eq!(client.post_slice("/v1/suggest", payload.as_bytes()).unwrap(), 200);
    }
    let allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
    assert_eq!(
        allocs, 0,
        "HTTP+JSON layers performed {allocs} buffer growths over 300 traced suggests"
    );
    let scratch_growths = handle.bandit_scratch_growths() - scratch_before;
    assert_eq!(scratch_growths, 0, "bandit scratch grew under tracing");
    // Every request recorded at least ReqStart + Suggest + ReqEnd.
    let recorded = handle.recorder().recorded() - recorded_before;
    assert!(recorded >= 900, "only {recorded} events recorded over 300 suggests");

    // The ring drains over HTTP…
    let (status, resp) = client.get("/v1/trace?since=0&limit=200").unwrap();
    assert_eq!(status, 200, "{resp:?}");
    let events = resp.get("events").and_then(Json::as_arr).expect("events array");
    assert!(!events.is_empty());
    assert!(resp.get("next_since").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
    // …and the per-session debug view exposes the arm statistics.
    let (status, resp) = client
        .get("/v1/debug/session?client_id=steady-trace&app=clomp&device=maxn&alpha=1.0&beta=0.0")
        .unwrap();
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("suggests").and_then(Json::as_f64), Some(320.0));
    assert!(resp.get("arms").and_then(Json::as_arr).map_or(false, |a| !a.is_empty()));

    drop(client);
    handle.shutdown().unwrap();
    // The background writer flushed a decodable capture on shutdown.
    let file_events = lasp::obs::read_trace_file(&trace_path).expect("readable trace file");
    assert!(file_events.iter().any(|e| e.kind_name() == "suggest"), "no suggest events on disk");
    std::fs::remove_dir_all(&dir).ok();
}

/// First value of a `/metrics` family, requiring the space separator so
/// `lasp_serve_sessions` never matches `lasp_serve_sessions_created_total`.
fn metric_value(text: &str, name: &str) -> f64 {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Some(v) = rest.strip_prefix(' ').and_then(|r| r.trim().parse::<f64>().ok()) {
                return v;
            }
        }
    }
    0.0
}

/// One report payload for session `dup-{c}`, deterministic in (c, seq) so
/// both injected copies of a pair are byte-identical duplicates.
fn dup_report(c: usize, seq: u64) -> String {
    let arm = (seq as usize * 3 + c) % 25;
    body(
        &format!("dup-{c}"),
        "clomp",
        &[
            ("arm", Json::Num(arm as f64)),
            ("time_s", Json::Num(0.5 + (arm % 7) as f64 * 0.1)),
            ("power_w", Json::Num(5.0)),
            ("seq", Json::Num(seq as f64)),
        ],
    )
}

#[test]
fn mixed_single_and_batch_report_traffic_keeps_seq_dedup_exact() {
    // Four threads drive the SAME four sessions concurrently — two via
    // single `/v1/report`, two via `/v1/report/batch` — and every
    // (client, seq) pair is injected exactly twice. The per-session
    // idempotency window must absorb exactly one copy of each pair, in
    // ANY interleaving: `lasp_serve_reports_deduped_total` equals the
    // injected duplicate count, and each session's ArmStats sees each
    // seq exactly once.
    const SEQS: u64 = 40;
    const CLIENTS: u64 = 4;
    let handle = boot(8, 4);
    let addr = handle.addr().to_string();

    let mut threads = vec![];
    for pair in [[0usize, 1], [2, 3]] {
        // One copy of each (client, seq) as single requests…
        let addr_single = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(&addr_single).unwrap();
            for seq in 0..SEQS {
                for c in pair {
                    let payload = dup_report(c, seq);
                    let status = client.post_slice("/v1/report", payload.as_bytes()).unwrap();
                    assert_eq!(status, 202);
                }
            }
        }));
        // …and the second copy through the batch endpoint, 16 entries
        // per request spanning both overlapping sessions of the pair.
        let addr_batch = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(&addr_batch).unwrap();
            for chunk in 0..SEQS / 8 {
                let entries: Vec<String> = (chunk * 8..(chunk + 1) * 8)
                    .flat_map(|seq| pair.map(|c| dup_report(c, seq)))
                    .collect();
                let payload = format!("{{\"entries\":[{}]}}", entries.join(","));
                let status =
                    client.post_slice("/v1/report/batch", payload.as_bytes()).unwrap();
                assert_eq!(status, 202);
                let resp = JsonSlice::parse(client.last_body()).unwrap();
                assert_eq!(resp.get("queued").and_then(|v| v.as_usize()), Some(16));
                assert_eq!(resp.get("dropped").and_then(|v| v.as_usize()), Some(0));
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }

    // Every injected report either applied or deduped — wait for the
    // shard workers to settle, then the split must be exactly half/half.
    let total = (2 * CLIENTS * SEQS) as f64;
    let uniques = (CLIENTS * SEQS) as f64;
    let mut probe = HttpClient::connect(&addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    let text = loop {
        let (status, page) = probe.get("/metrics").unwrap();
        assert_eq!(status, 200);
        let text = page.as_str().unwrap_or_default().to_string();
        let settled = metric_value(&text, "lasp_serve_reports_applied_total")
            + metric_value(&text, "lasp_serve_reports_deduped_total");
        if settled >= total {
            break text;
        }
        assert!(Instant::now() < deadline, "reports never settled: {settled}/{total}");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(metric_value(&text, "lasp_serve_reports_enqueued_total"), total, "{text}");
    assert_eq!(metric_value(&text, "lasp_serve_reports_dropped_total"), 0.0, "{text}");
    assert_eq!(
        metric_value(&text, "lasp_serve_reports_applied_total"),
        uniques,
        "each (client, seq) pair must apply exactly once"
    );
    assert_eq!(
        metric_value(&text, "lasp_serve_reports_deduped_total"),
        uniques,
        "deduped count must equal the injected duplicate count"
    );

    // And per-session: each of the 4 overlapping sessions saw each seq once.
    for c in 0..CLIENTS {
        let q = format!("/v1/best?client_id=dup-{c}&app=clomp&device=maxn&alpha=1.0&beta=0.0");
        let (status, b) = probe.get(&q).unwrap();
        assert_eq!(status, 200, "{b:?}");
        assert_eq!(
            b.get("total_pulls").and_then(Json::as_f64),
            Some(SEQS as f64),
            "session dup-{c} double-counted a duplicate: {b:?}"
        );
    }
    drop(probe);
    handle.shutdown().unwrap();
}

#[test]
fn steady_state_batch_suggest_is_allocation_free_end_to_end() {
    // The zero-allocation contract with batching enabled: after warmup,
    // a mixed single + 16-entry-batch suggest stream must grow neither
    // the HTTP/JSON buffers (including the per-worker batch arena feeding
    // them) nor any session's bandit scratch.
    let handle = boot(2, 2);
    let addr = handle.addr().to_string();
    let stats = handle.transport_stats();
    let mut client = HttpClient::connect(&addr).unwrap();
    let single = body("steady-batch", "clomp", &[]);
    let entries: Vec<String> =
        (0..16).map(|i| body(&format!("steady-batch-{i}"), "clomp", &[])).collect();
    let batch = format!("{{\"entries\":[{}]}}", entries.join(","));

    // Warmup: transport buffers, the batch arena, and every session's
    // scoring scratch reach their high-water marks.
    for _ in 0..20 {
        assert_eq!(client.post_slice("/v1/suggest/batch", batch.as_bytes()).unwrap(), 200);
        assert_eq!(client.post_slice("/v1/suggest", single.as_bytes()).unwrap(), 200);
    }
    let resp = JsonSlice::parse(client.last_body()).unwrap();
    assert!(resp.get("arm").is_some(), "single suggest still answers under batching");

    let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
    let scratch_before = handle.bandit_scratch_growths();
    for _ in 0..300 {
        assert_eq!(client.post_slice("/v1/suggest/batch", batch.as_bytes()).unwrap(), 200);
        assert_eq!(client.post_slice("/v1/suggest", single.as_bytes()).unwrap(), 200);
    }
    let allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
    assert_eq!(
        allocs, 0,
        "HTTP+JSON layers performed {allocs} buffer growths over 300 mixed batch rounds"
    );
    let scratch_growths = handle.bandit_scratch_growths() - scratch_before;
    assert_eq!(scratch_growths, 0, "a bandit scratch grew under steady-state batching");

    // The batched response is fully formed: 16 per-entry results, each
    // carrying a concrete arm and configuration.
    assert_eq!(client.post_slice("/v1/suggest/batch", batch.as_bytes()).unwrap(), 200);
    let resp = JsonSlice::parse(client.last_body()).unwrap();
    assert_eq!(resp.get("count").and_then(|v| v.as_usize()), Some(16));
    let mut seen = 0usize;
    for item in resp.get("results").expect("results").items() {
        assert!(item.get("arm").and_then(|v| v.as_usize()).is_some());
        let config = item.get("config").and_then(|c| c.as_str()).expect("config string");
        assert!(!config.is_empty());
        seen += 1;
    }
    assert_eq!(seen, 16);
    drop(client);
    handle.shutdown().unwrap();
}

#[test]
fn epsilon_policy_serves_over_http() {
    // PolicyKind::Epsilon rides the same serve surfaces as every other
    // policy (the old Policy trait silently dropped it from checkpoints;
    // the checkpoint/fleet round-trips are covered in serve/checkpoint.rs
    // and rust/tests/fleet_sync.rs).
    let handle = boot(2, 2);
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();
    let payload = body("eps", "clomp", &[("policy", Json::Str("epsilon".to_string()))]);
    for _ in 0..5 {
        let status = client.post_slice("/v1/suggest", payload.as_bytes()).unwrap();
        assert_eq!(status, 200);
    }
    let (status, resp) = client
        .get("/v1/best?client_id=eps&app=clomp&device=maxn&alpha=1.0&beta=0.0&policy=epsilon")
        .unwrap();
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("policy").and_then(Json::as_str), Some("epsilon-greedy"));
    drop(client);
    handle.shutdown().unwrap();
}
