//! Serve-layer integration: boot the tuning service on an ephemeral port,
//! drive mixed suggest/report traffic from many client threads, restart
//! the server from its checkpoint directory, and assert the learned
//! bandit state (pull counts / per-arm means) survived.

use lasp::serve::{start, HttpClient, ServeConfig};
use lasp::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

fn test_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("lasp-serve-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn cfg_with_dir(dir: &std::path::Path) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        // One worker per concurrent keep-alive client (8 traffic threads):
        // the fixed pool bounds concurrent connections by design.
        workers: 8,
        shards: 4,
        queue_cap: 1024,
        max_batch: 64,
        checkpoint_dir: Some(dir.to_path_buf()),
        // Effectively manual: the test drives snapshots via /v1/checkpoint
        // and the final shutdown snapshot.
        checkpoint_every: Duration::from_secs(3600),
        warm_retain: 0.5,
        ..ServeConfig::default()
    }
}

fn body(client: &str, app: &str, extra: &[(&str, Json)]) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("client_id".to_string(), Json::Str(client.to_string()));
    obj.insert("app".to_string(), Json::Str(app.to_string()));
    obj.insert("device".to_string(), Json::Str("maxn".to_string()));
    obj.insert("alpha".to_string(), Json::Num(1.0));
    obj.insert("beta".to_string(), Json::Num(0.0));
    for (k, v) in extra {
        obj.insert((*k).to_string(), v.clone());
    }
    Json::Obj(obj)
}

/// Synthetic measurement: arm-determined, so the bandit sees a stationary
/// landscape without needing the device simulator in the loop.
fn fake_time(arm: usize) -> f64 {
    0.5 + (arm % 17) as f64 * 0.15
}

fn best_query(client: &str, app: &str) -> String {
    format!("/v1/best?client_id={client}&app={app}&device=maxn&alpha=1.0&beta=0.0")
}

fn wait_until<F: FnMut() -> bool>(mut cond: F, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    cond()
}

#[test]
fn mixed_traffic_checkpoint_restart_preserves_state() {
    let dir = test_dir("restart");
    let handle = start(cfg_with_dir(&dir)).unwrap();
    let addr = handle.addr().to_string();

    // Drive mixed suggest/report traffic from many concurrent clients:
    // 8 threads x 40 rounds across three apps.
    let apps = ["clomp", "kripke", "lulesh"];
    let rounds_per_client = 40usize;
    let mut workers = vec![];
    for t in 0..8usize {
        let addr = addr.clone();
        let app = apps[t % apps.len()].to_string();
        workers.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(&addr).unwrap();
            let client_id = format!("it-{t}");
            for _ in 0..rounds_per_client {
                let (status, resp) =
                    client.post("/v1/suggest", &body(&client_id, &app, &[])).unwrap();
                assert_eq!(status, 200, "suggest failed: {resp:?}");
                let arm = resp.get("arm").and_then(Json::as_usize).unwrap();
                let (status, resp) = client
                    .post(
                        "/v1/report",
                        &body(
                            &client_id,
                            &app,
                            &[
                                ("arm", Json::Num(arm as f64)),
                                ("time_s", Json::Num(fake_time(arm))),
                                ("power_w", Json::Num(5.0)),
                            ],
                        ),
                    )
                    .unwrap();
                assert_eq!(status, 202, "report not queued: {resp:?}");
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }

    let mut probe = HttpClient::connect(&addr).unwrap();

    // Health and metrics surfaces are alive and consistent.
    let (status, health) = probe.get("/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(health.get("sessions").and_then(Json::as_usize), Some(8));

    // Reports are applied asynchronously; wait for every shard's batched
    // updater to drain before snapshotting expectations.
    let expected_pulls = rounds_per_client as f64;
    for t in 0..8usize {
        let app = apps[t % apps.len()];
        let q = best_query(&format!("it-{t}"), app);
        assert!(
            wait_until(
                || {
                    let (s, b) = probe.get(&q).unwrap();
                    s == 200
                        && b.get("total_pulls").and_then(Json::as_f64) == Some(expected_pulls)
                },
                Duration::from_secs(10)
            ),
            "reports never fully applied for it-{t}"
        );
    }

    // The metrics surface is alive and counting.
    let (status, metrics_page) = probe.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let metrics_text = metrics_page.as_str().unwrap_or_default().to_string();
    assert!(
        metrics_text.contains("lasp_serve_reports_applied_total 320"),
        "{metrics_text}"
    );
    assert!(metrics_text.contains("lasp_serve_suggest_latency_us_count"), "{metrics_text}");
    assert!(metrics_text.contains("lasp_serve_process_cpu_seconds"), "{metrics_text}");

    // Record the pre-restart answer for every client.
    let mut before = BTreeMap::new();
    for t in 0..8usize {
        let app = apps[t % apps.len()];
        let (status, b) = probe.get(&best_query(&format!("it-{t}"), app)).unwrap();
        assert_eq!(status, 200);
        let arm = b.get("arm").and_then(Json::as_usize).unwrap();
        let pulls = b.get("total_pulls").and_then(Json::as_f64).unwrap();
        let mean = b.get("mean_time_s").and_then(Json::as_f64);
        assert!(pulls >= expected_pulls, "pulls {pulls}");
        before.insert(t, (arm, mean));
    }

    // Snapshot explicitly, then shut down (which snapshots again).
    let (status, snap) = probe.post("/v1/checkpoint", &Json::Obj(BTreeMap::new())).unwrap();
    assert_eq!(status, 200, "{snap:?}");
    assert_eq!(snap.get("sessions").and_then(Json::as_usize), Some(8));
    drop(probe);
    handle.shutdown().unwrap();

    // Restart from the same directory (new ephemeral port).
    let handle2 = start(cfg_with_dir(&dir)).unwrap();
    assert_eq!(handle2.restored_sessions(), 8);
    let addr2 = handle2.addr().to_string();
    let mut probe2 = HttpClient::connect(&addr2).unwrap();

    for t in 0..8usize {
        let app = apps[t % apps.len()];
        let (status, b) = probe2.get(&best_query(&format!("it-{t}"), app)).unwrap();
        assert_eq!(status, 200, "session it-{t} lost across restart");
        let (arm_before, mean_before) = before[&t];
        // Discounting shrinks counts but preserves per-arm means, so the
        // Eq. 4 answer and its observed mean survive the restart.
        assert_eq!(
            b.get("arm").and_then(Json::as_usize),
            Some(arm_before),
            "tuned arm changed across restart for it-{t}"
        );
        let pulls = b.get("total_pulls").and_then(Json::as_f64).unwrap();
        assert!(pulls > 0.0, "no retained pulls for it-{t}");
        // Discounting never grows counts (per-arm floor is 1 pull).
        assert!(
            pulls <= expected_pulls,
            "retention grew counts: {pulls} vs {expected_pulls}"
        );
        if let (Some(mb), Some(ma)) = (mean_before, b.get("mean_time_s").and_then(Json::as_f64)) {
            assert!((mb - ma).abs() < 1e-9, "mean drifted: {mb} -> {ma}");
        }
        // And the session keeps learning after the restart.
        let (status, resp) = probe2.post("/v1/suggest", &body(&format!("it-{t}"), app, &[])).unwrap();
        assert_eq!(status, 200, "{resp:?}");
    }

    handle2.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn api_error_paths() {
    let handle = start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 2,
        checkpoint_dir: None,
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    // Unknown session -> 404.
    let (status, _) = client.get(&best_query("nobody", "clomp")).unwrap();
    assert_eq!(status, 404);

    // Malformed JSON -> 400.
    let (status, _) = client.post("/v1/suggest", &Json::Str("not an object".into())).unwrap();
    assert_eq!(status, 400);

    // Missing fields -> 400.
    let (status, _) = client
        .post("/v1/suggest", &Json::Obj(BTreeMap::new()))
        .unwrap();
    assert_eq!(status, 400);

    // Bad app -> 400.
    let (status, _) = client.post("/v1/suggest", &body("c", "doom", &[])).unwrap();
    assert_eq!(status, 400);

    // Report without measurement -> 400.
    let (status, _) = client.post("/v1/report", &body("c", "clomp", &[])).unwrap();
    assert_eq!(status, 400);

    // Checkpoint without a configured dir -> 400.
    let (status, _) = client.post("/v1/checkpoint", &Json::Obj(BTreeMap::new())).unwrap();
    assert_eq!(status, 400);

    // Unknown endpoint -> 404.
    let (status, _) = client.post("/v1/nope", &Json::Obj(BTreeMap::new())).unwrap();
    assert_eq!(status, 404);

    handle.shutdown().unwrap();
}

#[test]
fn subset_policy_serves_hypre_scale() {
    // The 92,160-arm Hypre space defaults to the subset policy; suggests
    // must stay inside the candidate set and reports must apply.
    let handle = start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 2,
        checkpoint_dir: None,
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();
    for _ in 0..30 {
        let (status, resp) = client.post("/v1/suggest", &body("hy", "hypre", &[])).unwrap();
        assert_eq!(status, 200);
        let arm = resp.get("arm").and_then(Json::as_usize).unwrap();
        assert!(arm < 92_160);
        let (status, _) = client
            .post(
                "/v1/report",
                &body(
                    "hy",
                    "hypre",
                    &[
                        ("arm", Json::Num(arm as f64)),
                        ("time_s", Json::Num(fake_time(arm))),
                        ("power_w", Json::Num(5.0)),
                    ],
                ),
            )
            .unwrap();
        assert_eq!(status, 202);
    }
    let mut probe = HttpClient::connect(&addr).unwrap();
    assert!(
        wait_until(
            || {
                let (s, b) = probe.get(&best_query("hy", "hypre")).unwrap();
                s == 200 && b.get("total_pulls").and_then(Json::as_f64) == Some(30.0)
            },
            Duration::from_secs(10)
        ),
        "hypre reports never applied"
    );
    let (status, b) = probe.get(&best_query("hy", "hypre")).unwrap();
    assert_eq!(status, 200);
    assert_eq!(b.get("policy").and_then(Json::as_str), Some("lasp-ucb1-subset"));
    handle.shutdown().unwrap();
}
