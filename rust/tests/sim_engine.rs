//! Scenario-engine acceptance tests.
//!
//! Three pillars:
//! 1. **Thread-count determinism** — one `ScenarioGrid` produces
//!    bit-for-bit identical traces at 1, 4 and 8 sweep threads.
//! 2. **Golden equivalence** — `harness::run_lasp` (now a thin wrapper
//!    over one engine cell) reproduces the frozen pre-refactor loop,
//!    copied verbatim below, arm for arm (same style as
//!    `rust/tests/policy_golden.rs`).
//! 3. **Expressiveness** — a mid-episode power-mode switch + noise burst
//!    across all four apps (inexpressible in the seed-era loops) runs
//!    through `lasp simulate`'s grid path and emits valid JSON.

use lasp::apps::{self, AppKind};
use lasp::bandit::{Policy, SubsetTuner, UcbTuner};
use lasp::device::{Device, JetsonNano, NoiseModel, PowerMode};
use lasp::sim::{Scenario, ScenarioGrid, StrategySpec, SweepRunner};
use lasp::util::json::Json;

// --- Frozen pre-refactor reference loop -----------------------------------

/// The seed-era `harness::lasp_policy`, copied verbatim.
fn ref_lasp_policy(
    k: usize,
    iterations: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
) -> Box<dyn Policy> {
    if k > iterations / 2 && k > 256 {
        let m = SubsetTuner::recommended_size(k, iterations);
        Box::new(SubsetTuner::new(k, m, alpha, beta, seed ^ 0xA5A5))
    } else {
        Box::new(UcbTuner::new(k, alpha, beta))
    }
}

/// The seed-era `harness::run_lasp` loop, copied verbatim.
#[allow(clippy::too_many_arguments)]
fn ref_run_lasp(
    kind: AppKind,
    mode: PowerMode,
    iterations: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
    noise: NoiseModel,
) -> (usize, Vec<f64>, Vec<usize>) {
    let app = apps::build(kind);
    let k = app.space().len();
    let mut device = JetsonNano::new(mode, seed)
        .with_fidelity(0.15)
        .with_injected_noise(noise);
    let mut tuner = ref_lasp_policy(k, iterations, alpha, beta, seed);
    let mut trace = Vec::with_capacity(iterations);
    for _ in 0..iterations {
        let arm = tuner.select();
        let m = device.run(&app.workload(arm, device.fidelity()));
        tuner.update(arm, m.time_s, m.power_w);
        trace.push(arm);
    }
    (tuner.most_selected(), tuner.counts().to_vec(), trace)
}

#[test]
fn run_lasp_reproduces_the_pre_refactor_loop() {
    // Small-space UCB path, the 5W mode, a noisy run, and Hypre's
    // subset path — each must match the frozen loop bit for bit.
    let cases: [(AppKind, PowerMode, usize, f64, f64, u64, NoiseModel); 4] = [
        (AppKind::Clomp, PowerMode::Maxn, 250, 1.0, 0.0, 3, NoiseModel::none()),
        (AppKind::Kripke, PowerMode::FiveW, 300, 0.8, 0.2, 11, NoiseModel::none()),
        (AppKind::Lulesh, PowerMode::Maxn, 200, 0.2, 0.8, 7, NoiseModel::uniform(0.10)),
        (AppKind::Hypre, PowerMode::Maxn, 400, 0.8, 0.2, 5, NoiseModel::none()),
    ];
    for (kind, mode, iters, alpha, beta, seed, noise) in cases {
        let (ref_best, ref_counts, ref_trace) =
            ref_run_lasp(kind, mode, iters, alpha, beta, seed, noise);
        let (best, counts, trace) =
            lasp::experiments::harness::run_lasp(kind, mode, iters, alpha, beta, seed, noise);
        for (i, (e, g)) in ref_trace.iter().zip(&trace).enumerate() {
            assert_eq!(
                g, e,
                "{kind}: engine diverged from the pre-refactor loop at iteration {i}"
            );
        }
        assert_eq!(best, ref_best, "{kind}: recommendation diverged");
        assert_eq!(counts, ref_counts, "{kind}: counts diverged");
    }
}

fn determinism_grid() -> ScenarioGrid {
    ScenarioGrid {
        apps: vec![AppKind::Clomp, AppKind::Kripke],
        objectives: vec![(1.0, 0.0), (0.2, 0.8)],
        strategies: vec![StrategySpec::Lasp, StrategySpec::SwUcb(0), StrategySpec::Random],
        seeds: vec![1, 2],
        iterations: 150,
        record_trace: true,
        ..Default::default()
    }
}

#[test]
fn sweep_results_identical_at_1_4_and_8_threads() {
    let grid = determinism_grid();
    let reference = SweepRunner::new(1).sweep(&grid).expect("1-thread sweep");
    for threads in [4, 8] {
        let got = SweepRunner::new(threads).sweep(&grid).expect("sweep");
        assert_eq!(got.outcomes.len(), reference.outcomes.len());
        for (i, (a, b)) in reference.outcomes.iter().zip(&got.outcomes).enumerate() {
            assert_eq!(
                a.trace, b.trace,
                "cell {i} ({}) trace differs at {threads} threads",
                reference.cells[i].label()
            );
            assert_eq!(a.best_index, b.best_index, "cell {i} best differs");
            assert_eq!(a.counts, b.counts, "cell {i} counts differ");
        }
        // The JSON artifact is byte-identical too.
        assert_eq!(reference.to_json(), got.to_json());
    }
}

#[test]
fn inexpressible_scenario_runs_and_emits_valid_json() {
    // Mid-episode power-mode switch + noise burst + bus contention across
    // all four apps: the seed-era loops had no vocabulary for any of
    // these. Parsed from the same TOML schema `lasp simulate` consumes.
    let grid = ScenarioGrid::from_toml_str(
        r#"
        [sim]
        apps = "all"
        strategies = "lasp"
        seeds = "1..3"
        iterations = 240
        record_trace = true
        events = "mode@80=5w, noise@120=0.15, bus@160=4x0.45, noise@200=0, clear@220"
        "#,
    )
    .expect("scenario parses");
    assert_eq!(grid.len(), 8);
    let result = SweepRunner::new(0).sweep(&grid).expect("sweep");
    let json = result.to_json();
    let parsed = Json::parse(&json).expect("valid JSON");
    let cells = parsed.get("results").and_then(|r| r.as_arr()).expect("results array");
    assert_eq!(cells.len(), 8);
    for cell in cells {
        let app: &str = cell.get("app").and_then(|v| v.as_str()).expect("app");
        let k = apps::build(app.parse().unwrap()).space().len();
        let best = cell.get("best_index").and_then(|v| v.as_usize()).expect("best_index");
        assert!(best < k, "{app}: best arm out of range");
        assert_eq!(cell.get("events").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(
            cell.get("trace").and_then(|t| t.as_arr()).map(|t| t.len()),
            Some(240)
        );
    }

    // The events are real: the same grid without them must agree before
    // iteration 80 (identical draws) and burn measurably less simulated
    // device time afterwards (5W is slower and bus contention stretches
    // memory-bound runs).
    let mut calm = grid.clone();
    calm.events.clear();
    let calm_result = SweepRunner::new(0).sweep(&calm).expect("calm sweep");
    for (eventful, quiet) in result.outcomes.iter().zip(&calm_result.outcomes) {
        let (e_trace, q_trace) =
            (eventful.trace.as_ref().unwrap(), quiet.trace.as_ref().unwrap());
        assert_eq!(e_trace[..80], q_trace[..80], "prefix must agree");
        assert!(
            eventful.simulated_device_seconds > quiet.simulated_device_seconds,
            "events had no effect on device time"
        );
    }
}

#[test]
fn episode_steps_are_counted() {
    let before = lasp::sim::steps_executed();
    let cell = Scenario::lasp(AppKind::Clomp, PowerMode::Maxn, 64, 1);
    lasp::sim::run_scenario(&cell).expect("cell");
    assert!(lasp::sim::steps_executed() >= before + 64);
}

#[test]
fn tuning_session_still_matches_the_engine() {
    // TuningSession is a thin wrapper over the same episode stepper: its
    // outcome must agree with the equivalent scenario cell.
    use lasp::tuning::{SessionConfig, TuningSession};
    let mut session = TuningSession::new(
        apps::build(AppKind::Clomp),
        Box::new(JetsonNano::new(PowerMode::Maxn, 42).with_fidelity(0.15)),
        SessionConfig { iterations: 180, alpha: 1.0, beta: 0.0, record_history: true },
    );
    let out = session.run().expect("session");
    let cell = Scenario::lasp(AppKind::Clomp, PowerMode::Maxn, 180, 42)
        .with_objective(1.0, 0.0)
        .with_strategy(StrategySpec::Ucb);
    let engine = lasp::sim::run_scenario(&cell).expect("cell");
    assert_eq!(out.best_index, engine.best_index);
    assert_eq!(out.history.len(), 180);
    assert_eq!(Some(out.counts), engine.counts);
}
