//! Capture → replay round trip: record a live loadgen run against the
//! serve stack (`LoadgenConfig::record`), then feed the capture back
//! through the sim engine's `replay` strategy and pin that the replay is
//! bit-identical at any sweep thread count — the observability tentpole's
//! determinism contract.

use lasp::apps::AppKind;
use lasp::device::PowerMode;
use lasp::obs;
use lasp::serve::{loadgen, LoadgenConfig, ServeConfig};
use lasp::sim::{Scenario, StrategySpec, SweepResult, SweepRunner};
use std::process::Command;

fn record_capture(path: &std::path::Path, rounds: usize) {
    let handle = lasp::serve::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        shards: 4,
        checkpoint_dir: None,
        ..Default::default()
    })
    .expect("boot serve");
    let report = loadgen::run(&LoadgenConfig {
        addr: handle.addr().to_string(),
        sessions: 8,
        rounds,
        threads: 4,
        apps: vec![AppKind::Clomp],
        record: Some(path.to_path_buf()),
        ..Default::default()
    })
    .expect("loadgen");
    assert_eq!(report.errors, 0, "loadgen errors while recording");
    handle.shutdown().expect("shutdown");
}

fn replay_cells(path: &str, rounds: usize) -> Vec<Scenario> {
    // Loadgen alternates session modes, so the capture covers both cells.
    [PowerMode::Maxn, PowerMode::FiveW]
        .into_iter()
        .map(|mode| {
            Scenario::lasp(AppKind::Clomp, mode, rounds, 42)
                .with_strategy(StrategySpec::Replay)
                .with_trace(path)
                .recording_trace()
        })
        .collect()
}

#[test]
fn recorded_loadgen_run_replays_bit_identically_at_any_thread_count() {
    let dir = std::env::temp_dir().join(format!("lasp-trace-replay-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let capture = dir.join("loadgen.lasptrc");
    let rounds = 256;
    record_capture(&capture, rounds);

    // Every loadgen round left exactly one Measure event in the capture.
    let events = obs::read_trace_file(&capture).expect("readable capture");
    let measures: Vec<_> = events.iter().filter_map(obs::decode_measure).collect();
    assert_eq!(measures.len(), rounds, "one measurement per round");
    assert!(measures.iter().all(|&(app, _, arm, t, p)| {
        app == AppKind::Clomp && arm < 125 && t > 0.0 && p > 0.0
    }));

    let cells = replay_cells(capture.to_str().unwrap(), rounds);
    let jsons: Vec<String> = [1usize, 4, 1]
        .iter()
        .map(|&threads| {
            let outcomes = SweepRunner::new(threads).run(&cells).expect("replay sweep");
            SweepResult { cells: cells.clone(), outcomes }.to_json()
        })
        .collect();
    assert_eq!(jsons[0], jsons[1], "replay diverged between 1 and 4 threads");
    assert_eq!(jsons[0], jsons[2], "replay is not re-runnable");

    // The replayed arm sequence is exactly the capture's, per cell.
    let outcomes = SweepRunner::new(2).run(&cells).expect("replay sweep");
    for (cell, outcome) in cells.iter().zip(&outcomes) {
        let expected: Vec<usize> = measures
            .iter()
            .filter(|&&(app, mode, _, _, _)| app == cell.app && mode == cell.mode)
            .map(|&(_, _, arm, _, _)| arm)
            .collect();
        assert!(!expected.is_empty(), "capture has no events for {}", cell.label());
        assert_eq!(outcome.evaluations, expected.len());
        assert_eq!(outcome.trace.as_deref(), Some(expected.as_slice()));
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_cli_decodes_a_capture() {
    let dir = std::env::temp_dir().join(format!("lasp-trace-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let capture = dir.join("cli.lasptrc");
    record_capture(&capture, 64);

    let out = Command::new(env!("CARGO_BIN_EXE_lasp"))
        .args(["trace", "stats", "--file", capture.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("events: 64"), "{text}");
    assert!(text.contains("measure"), "{text}");

    let out = Command::new(env!("CARGO_BIN_EXE_lasp"))
        .args(["trace", "dump", "--file", capture.to_str().unwrap(), "--format", "csv"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.starts_with("seq,t_us,kind,a,b,c"), "{text}");
    assert_eq!(text.lines().count(), 65, "header + one row per event");

    let out = Command::new(env!("CARGO_BIN_EXE_lasp"))
        .args(["trace", "dump", "--file", capture.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("\"kind\":\"measure\""), "{text}");
    // Semantic decode: app/mode names, not packed words.
    assert!(text.contains("\"app\":\"clomp\""), "{text}");

    // A non-trace file is rejected up front.
    let bogus = dir.join("not-a-trace.bin");
    std::fs::write(&bogus, b"hello world, definitely not LASPTRC1").unwrap();
    let out = Command::new(env!("CARGO_BIN_EXE_lasp"))
        .args(["trace", "stats", "--file", bogus.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_and_corrupt_captures_fail_cleanly() {
    // A capture cut off mid-record (a crashed writer, a partial copy) and
    // a capture with a garbled header must both surface as clean errors —
    // from the CLI and from the sim engine's replay strategy — never as a
    // panic or a silently-shortened replay. Every fixture gets a unique
    // path: the replay layer memoizes parsed captures per path.
    let dir = std::env::temp_dir().join(format!("lasp-trace-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Start from a small valid capture so the truncation is realistic.
    let valid = dir.join("valid.lasptrc");
    record_capture(&valid, 8);
    let bytes = std::fs::read(&valid).unwrap();
    assert!(bytes.len() > 58, "capture too small to truncate meaningfully");

    let truncated = dir.join("truncated.lasptrc");
    std::fs::write(&truncated, &bytes[..bytes.len() - 17]).unwrap();
    let corrupt = dir.join("corrupt.lasptrc");
    let mut garbled = bytes.clone();
    garbled[..8].copy_from_slice(b"NOTATRCE");
    std::fs::write(&corrupt, &garbled).unwrap();

    for (path, needle) in
        [(&truncated, "record size"), (&corrupt, "not a LASP trace file")]
    {
        // `lasp trace dump` exits non-zero with a diagnostic on stderr.
        let out = Command::new(env!("CARGO_BIN_EXE_lasp"))
            .args(["trace", "dump", "--file", path.to_str().unwrap()])
            .output()
            .unwrap();
        assert!(!out.status.success(), "dump accepted {}", path.display());
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "stderr for {}: {stderr}", path.display());

        // The replay strategy reports the same failure as a clean Err.
        let err = lasp::sim::ReplayStep::from_file(
            path.to_str().unwrap(),
            AppKind::Clomp,
            PowerMode::Maxn,
            125,
            1.0,
            0.0,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains(needle), "replay error for {}: {err}", path.display());
    }

    std::fs::remove_dir_all(&dir).ok();
}
