//! Differential test: the reactor and the legacy blocking transport are
//! interchangeable backends behind one seam, so for an identical request
//! stream they must produce bit-identical response bytes — and, because
//! buffer-growth accounting lives in code shared by both, identical
//! `alloc_events` counts. The same harness then certifies the reactor's
//! steady-state zero-allocation contract end to end, batch endpoints
//! included.
//!
//! Corpus discipline for exact alloc parity: the whole deterministic
//! corpus rides ONE keep-alive connection per server (one `ConnBuf` per
//! side: per-connection on the reactor, per-worker on the blocking pool
//! with `workers = 1`), every request stays under the 4 KiB initial read
//! buffer, and the single oversized-header request — the only input that
//! grows a read buffer — runs last, on a fresh connection for both.

#![cfg(unix)]

use lasp::serve::{start, HttpClient, ServeConfig, ServerHandle, TransportKind};
use lasp::util::json::JsonSlice;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

fn boot(kind: TransportKind) -> ServerHandle {
    start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        // One worker / one event loop: exactly one read buffer, one
        // response buffer, and one frame buffer per server, so growth
        // event counts are comparable by construction.
        workers: 1,
        event_loops: 1,
        transport: kind,
        shards: 2,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .unwrap()
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one full HTTP response (head + declared body) off `s`.
fn read_one_response(s: &mut TcpStream) -> Vec<u8> {
    let mut raw = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        if let Some(hdr_end) = find_subsequence(&raw, b"\r\n\r\n") {
            let head = String::from_utf8_lossy(&raw[..hdr_end]);
            let clen: usize = head
                .lines()
                .filter_map(|l| l.split_once(':'))
                .find(|(name, _)| name.trim().eq_ignore_ascii_case("content-length"))
                .and_then(|(_, value)| value.trim().parse().ok())
                .unwrap_or(0);
            if raw.len() >= hdr_end + 4 + clen {
                raw.truncate(hdr_end + 4 + clen);
                return raw;
            }
        }
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "connection closed early: {}", String::from_utf8_lossy(&raw));
        raw.extend_from_slice(&buf[..n]);
    }
}

fn post_frame(path: &str, body: &str) -> Vec<u8> {
    format!(
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn get_frame(path_and_query: &str) -> Vec<u8> {
    format!("GET {path_and_query} HTTP/1.1\r\nHost: x\r\n\r\n").into_bytes()
}

fn suggest_body(client: &str, app: &str) -> String {
    format!(
        "{{\"client_id\":\"{client}\",\"app\":\"{app}\",\"device\":\"maxn\",\
         \"alpha\":1.0,\"beta\":0.0}}"
    )
}

fn report_body(client: &str, app: &str, arm: usize) -> String {
    format!(
        "{{\"client_id\":\"{client}\",\"app\":\"{app}\",\"device\":\"maxn\",\
         \"alpha\":1.0,\"beta\":0.0,\"arm\":{arm},\"time_s\":0.5,\"power_w\":5.0}}"
    )
}

fn batch_body(prefix: &str, n: usize, with_measurement: bool) -> String {
    let entries: Vec<String> = (0..n)
        .map(|i| {
            if with_measurement {
                report_body(&format!("{prefix}-{i}"), "clomp", 2)
            } else {
                suggest_body(&format!("{prefix}-{i}"), "clomp")
            }
        })
        .collect();
    format!("{{\"entries\":[{}]}}", entries.join(","))
}

/// The deterministic corpus: every hot-path endpoint whose response
/// depends only on the request stream (no uptime, no latency counters).
fn corpus() -> Vec<(&'static str, Vec<u8>)> {
    vec![
        ("suggest-a", post_frame("/v1/suggest", &suggest_body("diff-a", "clomp"))),
        ("suggest-b", post_frame("/v1/suggest", &suggest_body("diff-b", "kripke"))),
        ("suggest-a-again", post_frame("/v1/suggest", &suggest_body("diff-a", "clomp"))),
        ("report-a", post_frame("/v1/report", &report_body("diff-a", "clomp", 3))),
        ("suggest-batch", post_frame("/v1/suggest/batch", &batch_body("diff-batch", 8, false))),
        ("report-batch", post_frame("/v1/report/batch", &batch_body("diff-batch", 8, true))),
        ("missing-endpoint", get_frame("/v1/nope")),
        ("bad-query", get_frame("/v1/best?client_id=%FF&app=clomp")),
        (
            "best-unknown-session",
            get_frame("/v1/best?client_id=ghost&app=clomp&device=maxn&alpha=1.0&beta=0.0"),
        ),
    ]
}

/// Protocol-violation frames, each served on a fresh connection. The
/// oversized-header case is last: it is the one input that grows a read
/// buffer, and parity needs both servers to meet it exactly once, from
/// the same buffer high-water mark.
fn malformed_corpus() -> Vec<(&'static str, Vec<u8>)> {
    let mut many_headers = b"GET /healthz HTTP/1.1\r\n".to_vec();
    for i in 0..70 {
        many_headers.extend_from_slice(format!("X-H{i}: v\r\n").as_bytes());
    }
    many_headers.extend_from_slice(b"\r\n");
    let mut big_header = b"GET /healthz HTTP/1.1\r\nX-Pad: ".to_vec();
    let pad = big_header.len() + 20 * 1024;
    big_header.resize(pad, b'p');
    big_header.extend_from_slice(b"\r\n\r\n");
    vec![
        ("garbage-request-line", b"GARBAGE\r\n\r\n".to_vec()),
        (
            "transfer-encoding",
            b"POST /v1/suggest HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
        ),
        (
            "conflicting-length",
            b"POST /v1/suggest HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 3\r\n\r\nab"
                .to_vec(),
        ),
        (
            "oversized-body",
            b"POST /v1/suggest HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n".to_vec(),
        ),
        ("too-many-headers", many_headers),
        ("oversized-header", big_header),
    ]
}

/// Drive the full corpus against one server; returns the raw response
/// bytes in corpus order.
fn drive(addr: std::net::SocketAddr) -> Vec<(&'static str, Vec<u8>)> {
    let mut out = Vec::new();
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for (name, frame) in corpus() {
        conn.write_all(&frame).unwrap();
        out.push((name, read_one_response(&mut conn)));
    }

    // Reports drain asynchronously through the shard queues: poll (with
    // the same frame, so both servers see identical poll traffic shapes)
    // until the report landed, then byte-compare the settled view.
    let best = get_frame("/v1/best?client_id=diff-a&app=clomp&device=maxn&alpha=1.0&beta=0.0");
    let deadline = Instant::now() + Duration::from_secs(10);
    let settled = loop {
        conn.write_all(&best).unwrap();
        let resp = read_one_response(&mut conn);
        let body_at = find_subsequence(&resp, b"\r\n\r\n").unwrap() + 4;
        let pulls = JsonSlice::parse(&resp[body_at..])
            .ok()
            .and_then(|v| v.get("total_pulls")?.as_usize());
        if pulls == Some(1) {
            break resp;
        }
        assert!(Instant::now() < deadline, "report never applied");
        std::thread::sleep(Duration::from_millis(10));
    };
    out.push(("best-settled", settled));
    conn.write_all(&get_frame(
        "/v1/debug/session?client_id=diff-a&app=clomp&device=maxn&alpha=1.0&beta=0.0",
    ))
    .unwrap();
    out.push(("debug-session", read_one_response(&mut conn)));

    // Timing-dependent bodies: compare the status line only.
    for (name, frame) in [("healthz", get_frame("/healthz")), ("metrics", get_frame("/metrics"))]
    {
        conn.write_all(&frame).unwrap();
        let resp = read_one_response(&mut conn);
        let status = resp.split(|&b| b == b'\r').next().unwrap_or(b"").to_vec();
        out.push((name, status));
    }
    drop(conn);

    for (name, frame) in malformed_corpus() {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(&frame).unwrap();
        out.push((name, read_one_response(&mut s)));
        // Dropping our side ends the server's linger early.
    }
    out
}

#[test]
fn both_transports_serve_bit_identical_responses_and_alloc_counts() {
    let reactor = boot(TransportKind::Reactor);
    let blocking = boot(TransportKind::Blocking);

    let from_reactor = drive(reactor.addr());
    let from_blocking = drive(blocking.addr());

    assert_eq!(from_reactor.len(), from_blocking.len());
    for ((name_r, bytes_r), (name_b, bytes_b)) in from_reactor.iter().zip(&from_blocking) {
        assert_eq!(name_r, name_b);
        assert_eq!(
            bytes_r,
            bytes_b,
            "transports diverged on `{name_r}`:\n reactor: {}\nblocking: {}",
            String::from_utf8_lossy(bytes_r),
            String::from_utf8_lossy(bytes_b)
        );
    }

    // Both counted at least the oversized-header read-buffer growth, and
    // the counts agree exactly — the shared-accounting guarantee.
    let allocs_reactor = reactor.transport_stats().alloc_events.load(Ordering::Relaxed);
    let allocs_blocking = blocking.transport_stats().alloc_events.load(Ordering::Relaxed);
    assert!(allocs_reactor > 0, "corpus must include at least one counted buffer growth");
    assert_eq!(
        allocs_reactor, allocs_blocking,
        "transports count buffer growth differently for an identical request stream"
    );

    reactor.shutdown().unwrap();
    blocking.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Routed-plane differential: shard-per-loop routing must be invisible on
// the wire. The same corpus — including batches whose entries span every
// shard (cross-owner on a multi-loop server) and duplicate `seq`s racing
// through different routes — must produce bit-identical responses and
// bit-identical settled session state on the blocking transport, a
// single-loop routed reactor, and a four-loop routed reactor.
// ---------------------------------------------------------------------------

fn boot_topology(kind: TransportKind, loops: usize, chaos: Option<lasp::chaos::ChaosConfig>) -> ServerHandle {
    start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        event_loops: loops,
        transport: kind,
        shards: 4,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        chaos,
        ..Default::default()
    })
    .unwrap()
}

fn report_body_seq(client: &str, app: &str, arm: usize, seq: u64) -> String {
    format!(
        "{{\"client_id\":\"{client}\",\"app\":\"{app}\",\"device\":\"maxn\",\
         \"alpha\":1.0,\"beta\":0.0,\"arm\":{arm},\"time_s\":0.5,\"power_w\":5.0,\
         \"seq\":{seq}}}"
    )
}

/// A report batch touching all eight `rt-*` sessions (keys spread by
/// hash over the 4-shard store), every entry carrying the same `seq`.
fn cross_owner_batch(seq: u64) -> String {
    let entries: Vec<String> =
        (0..8).map(|i| report_body_seq(&format!("rt-{i}"), "clomp", i % 4, seq)).collect();
    format!("{{\"entries\":[{}]}}", entries.join(","))
}

fn best_frame(client: &str) -> Vec<u8> {
    get_frame(&format!(
        "/v1/best?client_id={client}&app=clomp&device=maxn&alpha=1.0&beta=0.0"
    ))
}

fn body_pulls(resp: &[u8]) -> Option<usize> {
    let body_at = find_subsequence(resp, b"\r\n\r\n")? + 4;
    JsonSlice::parse(&resp[body_at..]).ok().and_then(|v| v.get("total_pulls")?.as_usize())
}

/// Poll `/v1/best` for `client` until `total_pulls == want`, then return
/// the settled response bytes.
fn settle(conn: &mut TcpStream, client: &str, want: usize) -> Vec<u8> {
    let frame = best_frame(client);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        conn.write_all(&frame).unwrap();
        let resp = read_one_response(conn);
        if body_pulls(&resp) == Some(want) {
            return resp;
        }
        assert!(
            Instant::now() < deadline,
            "{client} never settled at {want} pulls (last: {})",
            String::from_utf8_lossy(&resp)
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Drive the routing corpus against one server, returning every labelled
/// response in order.
fn drive_routed(addr: std::net::SocketAddr) -> Vec<(String, Vec<u8>)> {
    let mut out = Vec::new();
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // Eight sessions hash-spread over the four shards, created through
    // one connection — on the four-loop server this exercises connection
    // re-homing across owner loops.
    for i in 0..8 {
        let frame = post_frame("/v1/suggest", &suggest_body(&format!("rt-{i}"), "clomp"));
        conn.write_all(&frame).unwrap();
        out.push((format!("suggest-rt-{i}"), read_one_response(&mut conn)));
    }

    // Racing duplicate seqs, single-report path: the same (session, seq)
    // delivered twice back to back, then a fresh seq.
    for (label, frame) in [
        ("report-rt-0-seq1", post_frame("/v1/report", &report_body_seq("rt-0", "clomp", 1, 1))),
        ("report-rt-0-seq1-dup", post_frame("/v1/report", &report_body_seq("rt-0", "clomp", 1, 1))),
        ("report-rt-0-seq2", post_frame("/v1/report", &report_body_seq("rt-0", "clomp", 2, 2))),
    ] {
        conn.write_all(&frame).unwrap();
        out.push((label.to_string(), read_one_response(&mut conn)));
    }

    // Cross-owner batches with racing duplicate seqs: batch seq=10 twice
    // in a row (on the routed plane the first batch's foreign applies are
    // fire-and-forget, so the duplicate races the originals through the
    // owner mailboxes), then seq=11 once.
    for (label, seq) in [("batch-seq10", 10), ("batch-seq10-dup", 10), ("batch-seq11", 11)] {
        let frame = post_frame("/v1/report/batch", &cross_owner_batch(seq));
        conn.write_all(&frame).unwrap();
        out.push((label.to_string(), read_one_response(&mut conn)));
    }

    // Settled state: duplicates must have been absorbed exactly —
    // rt-0 saw seqs {1, 2, 10, 11}, everyone else {10, 11}.
    out.push(("settled-rt-0".to_string(), settle(&mut conn, "rt-0", 4)));
    for i in 1..8 {
        let client = format!("rt-{i}");
        out.push((format!("settled-{client}"), settle(&mut conn, &client, 2)));
    }
    for i in 0..8 {
        let client = format!("rt-{i}");
        conn.write_all(&get_frame(&format!(
            "/v1/debug/session?client_id={client}&app=clomp&device=maxn&alpha=1.0&beta=0.0"
        )))
        .unwrap();
        out.push((format!("debug-{client}"), read_one_response(&mut conn)));
    }
    out
}

#[test]
fn routed_plane_is_bit_identical_across_loop_counts() {
    let blocking = boot_topology(TransportKind::Blocking, 1, None);
    let one_loop = boot_topology(TransportKind::Reactor, 1, None);
    let four_loops = boot_topology(TransportKind::Reactor, 4, None);

    let base = drive_routed(blocking.addr());
    for (name, handle) in [("one-loop reactor", &one_loop), ("four-loop reactor", &four_loops)] {
        let got = drive_routed(handle.addr());
        assert_eq!(base.len(), got.len());
        for ((label_b, bytes_b), (label_g, bytes_g)) in base.iter().zip(&got) {
            assert_eq!(label_b, label_g);
            assert_eq!(
                bytes_b,
                bytes_g,
                "{name} diverged from blocking on `{label_b}`:\nblocking: {}\n  routed: {}",
                String::from_utf8_lossy(bytes_b),
                String::from_utf8_lossy(bytes_g)
            );
        }
    }

    blocking.shutdown().unwrap();
    one_loop.shutdown().unwrap();
    four_loops.shutdown().unwrap();
}

#[test]
fn routed_batches_stay_dedup_exact_under_flush_duplicate_chaos() {
    // flush_duplicate: 1.0 makes the apply path clone every report; the
    // seq window must absorb the clones on the routed plane exactly as it
    // does on the shared plane, even when the duplicates are injected on
    // foreign owner loops via batch routing.
    let handle = boot_topology(
        TransportKind::Reactor,
        4,
        Some(lasp::chaos::ChaosConfig {
            seed: 42,
            flush_duplicate: 1.0,
            ..Default::default()
        }),
    );
    let mut conn = TcpStream::connect(handle.addr()).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    for seq in 1..=5u64 {
        conn.write_all(&post_frame("/v1/report/batch", &cross_owner_batch(seq))).unwrap();
        let resp = read_one_response(&mut conn);
        assert!(resp.starts_with(b"HTTP/1.1 202"), "{}", String::from_utf8_lossy(&resp));
    }

    // Every session converges to exactly 5 pulls (5 distinct seqs) and
    // stays there: injected duplicates were counted as deduped, never as
    // extra reward.
    for i in 0..8 {
        let client = format!("rt-{i}");
        settle(&mut conn, &client, 5);
    }
    std::thread::sleep(Duration::from_millis(50));
    for i in 0..8 {
        let client = format!("rt-{i}");
        conn.write_all(&best_frame(&client)).unwrap();
        let resp = read_one_response(&mut conn);
        assert_eq!(
            body_pulls(&resp),
            Some(5),
            "{client} drifted past its distinct-seq count: {}",
            String::from_utf8_lossy(&resp)
        );
    }

    // The injected copies actually happened — and were absorbed.
    conn.write_all(&get_frame("/metrics")).unwrap();
    let metrics = read_one_response(&mut conn);
    let text = String::from_utf8_lossy(&metrics);
    let deduped: u64 = text
        .lines()
        .find_map(|l| l.strip_prefix("lasp_serve_reports_deduped_total "))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0);
    assert!(deduped >= 40, "expected >= 40 injected duplicates absorbed, saw {deduped}");

    drop(conn);
    handle.shutdown().unwrap();
}

#[test]
fn reactor_steady_state_is_allocation_free_including_batch_endpoints() {
    let handle = boot(TransportKind::Reactor);
    let addr = handle.addr().to_string();
    let stats = handle.transport_stats();
    let mut client = HttpClient::connect(&addr).unwrap();
    let single = suggest_body("steady-reactor", "clomp");
    let batch = batch_body("steady-reactor-batch", 16, false);

    // Warmup: the connection's read buffer, the loop's response/frame
    // buffers, the batch arena, and every session's scratch reach their
    // high-water marks.
    for _ in 0..20 {
        assert_eq!(client.post_slice("/v1/suggest", single.as_bytes()).unwrap(), 200);
        assert_eq!(client.post_slice("/v1/suggest/batch", batch.as_bytes()).unwrap(), 200);
    }
    let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
    let scratch_before = handle.bandit_scratch_growths();
    for _ in 0..300 {
        assert_eq!(client.post_slice("/v1/suggest", single.as_bytes()).unwrap(), 200);
        assert_eq!(client.post_slice("/v1/suggest/batch", batch.as_bytes()).unwrap(), 200);
    }
    let allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
    assert_eq!(
        allocs, 0,
        "reactor performed {allocs} buffer growths over 300 steady-state mixed rounds"
    );
    let scratch = handle.bandit_scratch_growths() - scratch_before;
    assert_eq!(scratch, 0, "bandit scratch grew under the reactor transport");
    drop(client);
    handle.shutdown().unwrap();
}
