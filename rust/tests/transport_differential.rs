//! Differential test: the reactor and the legacy blocking transport are
//! interchangeable backends behind one seam, so for an identical request
//! stream they must produce bit-identical response bytes — and, because
//! buffer-growth accounting lives in code shared by both, identical
//! `alloc_events` counts. The same harness then certifies the reactor's
//! steady-state zero-allocation contract end to end, batch endpoints
//! included.
//!
//! Corpus discipline for exact alloc parity: the whole deterministic
//! corpus rides ONE keep-alive connection per server (one `ConnBuf` per
//! side: per-connection on the reactor, per-worker on the blocking pool
//! with `workers = 1`), every request stays under the 4 KiB initial read
//! buffer, and the single oversized-header request — the only input that
//! grows a read buffer — runs last, on a fresh connection for both.

#![cfg(unix)]

use lasp::serve::{start, HttpClient, ServeConfig, ServerHandle, TransportKind};
use lasp::util::json::JsonSlice;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

fn boot(kind: TransportKind) -> ServerHandle {
    start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        // One worker / one event loop: exactly one read buffer, one
        // response buffer, and one frame buffer per server, so growth
        // event counts are comparable by construction.
        workers: 1,
        event_loops: 1,
        transport: kind,
        shards: 2,
        checkpoint_dir: None,
        checkpoint_every: Duration::from_secs(3600),
        ..Default::default()
    })
    .unwrap()
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one full HTTP response (head + declared body) off `s`.
fn read_one_response(s: &mut TcpStream) -> Vec<u8> {
    let mut raw = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        if let Some(hdr_end) = find_subsequence(&raw, b"\r\n\r\n") {
            let head = String::from_utf8_lossy(&raw[..hdr_end]);
            let clen: usize = head
                .lines()
                .filter_map(|l| l.split_once(':'))
                .find(|(name, _)| name.trim().eq_ignore_ascii_case("content-length"))
                .and_then(|(_, value)| value.trim().parse().ok())
                .unwrap_or(0);
            if raw.len() >= hdr_end + 4 + clen {
                raw.truncate(hdr_end + 4 + clen);
                return raw;
            }
        }
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "connection closed early: {}", String::from_utf8_lossy(&raw));
        raw.extend_from_slice(&buf[..n]);
    }
}

fn post_frame(path: &str, body: &str) -> Vec<u8> {
    format!(
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn get_frame(path_and_query: &str) -> Vec<u8> {
    format!("GET {path_and_query} HTTP/1.1\r\nHost: x\r\n\r\n").into_bytes()
}

fn suggest_body(client: &str, app: &str) -> String {
    format!(
        "{{\"client_id\":\"{client}\",\"app\":\"{app}\",\"device\":\"maxn\",\
         \"alpha\":1.0,\"beta\":0.0}}"
    )
}

fn report_body(client: &str, app: &str, arm: usize) -> String {
    format!(
        "{{\"client_id\":\"{client}\",\"app\":\"{app}\",\"device\":\"maxn\",\
         \"alpha\":1.0,\"beta\":0.0,\"arm\":{arm},\"time_s\":0.5,\"power_w\":5.0}}"
    )
}

fn batch_body(prefix: &str, n: usize, with_measurement: bool) -> String {
    let entries: Vec<String> = (0..n)
        .map(|i| {
            if with_measurement {
                report_body(&format!("{prefix}-{i}"), "clomp", 2)
            } else {
                suggest_body(&format!("{prefix}-{i}"), "clomp")
            }
        })
        .collect();
    format!("{{\"entries\":[{}]}}", entries.join(","))
}

/// The deterministic corpus: every hot-path endpoint whose response
/// depends only on the request stream (no uptime, no latency counters).
fn corpus() -> Vec<(&'static str, Vec<u8>)> {
    vec![
        ("suggest-a", post_frame("/v1/suggest", &suggest_body("diff-a", "clomp"))),
        ("suggest-b", post_frame("/v1/suggest", &suggest_body("diff-b", "kripke"))),
        ("suggest-a-again", post_frame("/v1/suggest", &suggest_body("diff-a", "clomp"))),
        ("report-a", post_frame("/v1/report", &report_body("diff-a", "clomp", 3))),
        ("suggest-batch", post_frame("/v1/suggest/batch", &batch_body("diff-batch", 8, false))),
        ("report-batch", post_frame("/v1/report/batch", &batch_body("diff-batch", 8, true))),
        ("missing-endpoint", get_frame("/v1/nope")),
        ("bad-query", get_frame("/v1/best?client_id=%FF&app=clomp")),
        (
            "best-unknown-session",
            get_frame("/v1/best?client_id=ghost&app=clomp&device=maxn&alpha=1.0&beta=0.0"),
        ),
    ]
}

/// Protocol-violation frames, each served on a fresh connection. The
/// oversized-header case is last: it is the one input that grows a read
/// buffer, and parity needs both servers to meet it exactly once, from
/// the same buffer high-water mark.
fn malformed_corpus() -> Vec<(&'static str, Vec<u8>)> {
    let mut many_headers = b"GET /healthz HTTP/1.1\r\n".to_vec();
    for i in 0..70 {
        many_headers.extend_from_slice(format!("X-H{i}: v\r\n").as_bytes());
    }
    many_headers.extend_from_slice(b"\r\n");
    let mut big_header = b"GET /healthz HTTP/1.1\r\nX-Pad: ".to_vec();
    let pad = big_header.len() + 20 * 1024;
    big_header.resize(pad, b'p');
    big_header.extend_from_slice(b"\r\n\r\n");
    vec![
        ("garbage-request-line", b"GARBAGE\r\n\r\n".to_vec()),
        (
            "transfer-encoding",
            b"POST /v1/suggest HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
        ),
        (
            "conflicting-length",
            b"POST /v1/suggest HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 3\r\n\r\nab"
                .to_vec(),
        ),
        (
            "oversized-body",
            b"POST /v1/suggest HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n".to_vec(),
        ),
        ("too-many-headers", many_headers),
        ("oversized-header", big_header),
    ]
}

/// Drive the full corpus against one server; returns the raw response
/// bytes in corpus order.
fn drive(addr: std::net::SocketAddr) -> Vec<(&'static str, Vec<u8>)> {
    let mut out = Vec::new();
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for (name, frame) in corpus() {
        conn.write_all(&frame).unwrap();
        out.push((name, read_one_response(&mut conn)));
    }

    // Reports drain asynchronously through the shard queues: poll (with
    // the same frame, so both servers see identical poll traffic shapes)
    // until the report landed, then byte-compare the settled view.
    let best = get_frame("/v1/best?client_id=diff-a&app=clomp&device=maxn&alpha=1.0&beta=0.0");
    let deadline = Instant::now() + Duration::from_secs(10);
    let settled = loop {
        conn.write_all(&best).unwrap();
        let resp = read_one_response(&mut conn);
        let body_at = find_subsequence(&resp, b"\r\n\r\n").unwrap() + 4;
        let pulls = JsonSlice::parse(&resp[body_at..])
            .ok()
            .and_then(|v| v.get("total_pulls")?.as_usize());
        if pulls == Some(1) {
            break resp;
        }
        assert!(Instant::now() < deadline, "report never applied");
        std::thread::sleep(Duration::from_millis(10));
    };
    out.push(("best-settled", settled));
    conn.write_all(&get_frame(
        "/v1/debug/session?client_id=diff-a&app=clomp&device=maxn&alpha=1.0&beta=0.0",
    ))
    .unwrap();
    out.push(("debug-session", read_one_response(&mut conn)));

    // Timing-dependent bodies: compare the status line only.
    for (name, frame) in [("healthz", get_frame("/healthz")), ("metrics", get_frame("/metrics"))]
    {
        conn.write_all(&frame).unwrap();
        let resp = read_one_response(&mut conn);
        let status = resp.split(|&b| b == b'\r').next().unwrap_or(b"").to_vec();
        out.push((name, status));
    }
    drop(conn);

    for (name, frame) in malformed_corpus() {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(&frame).unwrap();
        out.push((name, read_one_response(&mut s)));
        // Dropping our side ends the server's linger early.
    }
    out
}

#[test]
fn both_transports_serve_bit_identical_responses_and_alloc_counts() {
    let reactor = boot(TransportKind::Reactor);
    let blocking = boot(TransportKind::Blocking);

    let from_reactor = drive(reactor.addr());
    let from_blocking = drive(blocking.addr());

    assert_eq!(from_reactor.len(), from_blocking.len());
    for ((name_r, bytes_r), (name_b, bytes_b)) in from_reactor.iter().zip(&from_blocking) {
        assert_eq!(name_r, name_b);
        assert_eq!(
            bytes_r,
            bytes_b,
            "transports diverged on `{name_r}`:\n reactor: {}\nblocking: {}",
            String::from_utf8_lossy(bytes_r),
            String::from_utf8_lossy(bytes_b)
        );
    }

    // Both counted at least the oversized-header read-buffer growth, and
    // the counts agree exactly — the shared-accounting guarantee.
    let allocs_reactor = reactor.transport_stats().alloc_events.load(Ordering::Relaxed);
    let allocs_blocking = blocking.transport_stats().alloc_events.load(Ordering::Relaxed);
    assert!(allocs_reactor > 0, "corpus must include at least one counted buffer growth");
    assert_eq!(
        allocs_reactor, allocs_blocking,
        "transports count buffer growth differently for an identical request stream"
    );

    reactor.shutdown().unwrap();
    blocking.shutdown().unwrap();
}

#[test]
fn reactor_steady_state_is_allocation_free_including_batch_endpoints() {
    let handle = boot(TransportKind::Reactor);
    let addr = handle.addr().to_string();
    let stats = handle.transport_stats();
    let mut client = HttpClient::connect(&addr).unwrap();
    let single = suggest_body("steady-reactor", "clomp");
    let batch = batch_body("steady-reactor-batch", 16, false);

    // Warmup: the connection's read buffer, the loop's response/frame
    // buffers, the batch arena, and every session's scratch reach their
    // high-water marks.
    for _ in 0..20 {
        assert_eq!(client.post_slice("/v1/suggest", single.as_bytes()).unwrap(), 200);
        assert_eq!(client.post_slice("/v1/suggest/batch", batch.as_bytes()).unwrap(), 200);
    }
    let allocs_before = stats.alloc_events.load(Ordering::Relaxed);
    let scratch_before = handle.bandit_scratch_growths();
    for _ in 0..300 {
        assert_eq!(client.post_slice("/v1/suggest", single.as_bytes()).unwrap(), 200);
        assert_eq!(client.post_slice("/v1/suggest/batch", batch.as_bytes()).unwrap(), 200);
    }
    let allocs = stats.alloc_events.load(Ordering::Relaxed) - allocs_before;
    assert_eq!(
        allocs, 0,
        "reactor performed {allocs} buffer growths over 300 steady-state mixed rounds"
    );
    let scratch = handle.bandit_scratch_growths() - scratch_before;
    assert_eq!(scratch, 0, "bandit scratch grew under the reactor transport");
    drop(client);
    handle.shutdown().unwrap();
}
