//! Offline vendored subset of the `anyhow` error-handling API.
//!
//! This build runs with no network access, so the real crates.io `anyhow`
//! cannot be fetched; this path dependency provides the (small) slice of its
//! API the workspace actually uses, with identical call-site syntax:
//!
//! * [`Error`] — an opaque error value holding a human-readable cause chain;
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default error;
//! * [`anyhow!`] / [`bail!`] — format-string error construction;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Formatting matches `anyhow` where the workspace depends on it: `{e}`
//! prints the outermost message, `{e:#}` prints the whole chain joined by
//! `": "`, and `{e:?}` prints the chain in the multi-line "Caused by" form.

use std::fmt;

/// An opaque error: the cause chain as rendered strings, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with a higher-level context message (the new outermost entry).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost (most contextual) message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, `outer: inner: ...` like anyhow.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error of a `Result` or to a `None`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::from(io_err()).context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening: no such file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");

        fn f() -> Result<()> {
            bail!("nope {}", "x");
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope x");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
