//! Offline stub of the `xla-rs` PJRT binding surface used by
//! `rust/src/runtime/engine.rs`.
//!
//! This build has no network access and no PJRT shared library, so the
//! real `xla` crate cannot be fetched or linked. This stub provides the
//! exact types and signatures the runtime layer compiles against;
//! everything fails cleanly at runtime with [`Error::Unavailable`], which
//! the engine surfaces as "PJRT backend unavailable" — the scalar backend
//! (the default) is unaffected. Swap this path dependency for the real
//! `xla` crate to enable the AOT artifact path.

use std::path::Path;

/// Error type matching the `{e:?}` formatting the engine layer uses.
#[derive(Debug)]
pub enum Error {
    /// The stub is in place of the real PJRT binding.
    Unavailable(&'static str),
}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error::Unavailable(
        "xla/PJRT is stubbed in this offline build; link the real xla crate to enable it",
    ))
}

/// Marker for element types the literal accessors accept.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal value (stub: shape-only placeholder).
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal { _priv: () }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal { _priv: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    /// First element of the flattened literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// An XLA computation built from a parsed module.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device-side buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Construct the CPU client. Always fails in the stub — callers
    /// degrade to their scalar fallback.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Platform string for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_fails_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file(Path::new("/nope")).is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.get_first_element::<i32>().is_err());
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(Literal::scalar(1.0f32).to_tuple().is_err());
    }

    #[test]
    fn error_is_debug_formattable() {
        let e = PjRtClient::cpu().unwrap_err();
        let msg = format!("{e:?}");
        assert!(msg.contains("stubbed"), "{msg}");
    }
}
